"""Quickstart: the paper's T2DRL (DDQN caching + diffusion-actor D3PG
allocation) on the edge-AIGC environment, in ~40 lines of public API.

  PYTHONPATH=src python examples/quickstart.py

Training runs through the vectorized core: `num_envs=B` rolls out B edge
cells in parallel inside one compiled jax.lax.scan over episodes (multi-seed
for free — see DESIGN.md §6).  `num_envs=1` reproduces the legacy
single-env run exactly.
"""
import jax

from repro.core import (EnvCfg, T2DRLCfg, eval_t2drl, train_t2drl)

# 1. the paper's simulation setup (Table 2): 10 users, 10 GenAI models,
#    10 frames x 10 slots, 20 GB edge cache.
cfg = T2DRLCfg(
    env=EnvCfg(U=10, M=10, T=10, K=10, C=20.0),
    allocator="d3pg",       # diffusion-actor DDPG (the paper's D3PG)
    cacher="ddqn",          # long-timescale caching agent
    policy="shared",        # one learner fed by all cells (vector-env mode)
    L=5,                    # denoising steps (paper Fig. 6a optimum)
    lr_actor=1e-4, lr_critic=1e-3, lr_ddqn=1e-3,  # CI-scale tuned lrs
    episodes=80,
)

# 2. train — 4 heterogeneous edge cells in lockstep, one compiled call
ts, hist = train_t2drl(cfg, num_envs=4, log_every=20)

# 3. greedy evaluation (mean over episodes and cells)
ev = eval_t2drl(ts, cfg, episodes=5)
print("\n== greedy eval ==")
print(f"model hit ratio : {float(ev['hit_ratio']):.3f}")
print(f"total utility G : {float(ev['utility']):.2f}  (lower is better)")
print(f"mean slot reward: {float(ev['mean_reward']):.2f}")

# 4. compare against the random baseline on the SAME per-cell model zoos
#    (same init key -> same zoos; rewards are comparable).  NB: 80 episodes
#    is quickstart scale — the paper trains 500; see benchmarks/ for the
#    full method comparison at larger episode counts.
from repro.core import t2drl_init_batch
rcars = T2DRLCfg(env=cfg.env, allocator="rcars", cacher="random")
k_init, _ = jax.random.split(jax.random.PRNGKey(cfg.seed))
ev_r = eval_t2drl(t2drl_init_batch(k_init, rcars, 4), rcars, episodes=5)
print(f"\nRCARS baseline  : hit {float(ev_r['hit_ratio']):.3f} "
      f"reward {float(ev_r['mean_reward']):.2f}")
print(f"T2DRL           : hit {float(ev['hit_ratio']):.3f} "
      f"reward {float(ev['mean_reward']):.2f}  "
      "(objective: higher reward = lower delay+quality cost w/ deadlines)")

# 5. stress the trained policy on a registered workload scenario (flash
#    crowds pile most users onto one hot model every few slots — see
#    README.md "Scenario registry" and DESIGN.md §9).  The schedule only
#    modulates the env's draws, so the SAME train state and compiled eval
#    run it directly.
from repro.scenarios import build_scenario
burst = build_scenario("flash-crowd", cfg.env, num_envs=4)
ev_b = eval_t2drl(ts, cfg, episodes=5, mods=burst.mods)
print(f"\nT2DRL under flash-crowd bursts: hit {float(ev_b['hit_ratio']):.3f} "
      f"reward {float(ev_b['mean_reward']):.2f}")
