"""Quickstart: the paper's T2DRL (DDQN caching + diffusion-actor D3PG
allocation) on the edge-AIGC environment, in ~40 lines of public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (EnvCfg, T2DRLCfg, eval_t2drl, train_t2drl)

# 1. the paper's simulation setup (Table 2): 10 users, 10 GenAI models,
#    10 frames x 10 slots, 20 GB edge cache.
cfg = T2DRLCfg(
    env=EnvCfg(U=10, M=10, T=10, K=10, C=20.0),
    allocator="d3pg",       # diffusion-actor DDPG (the paper's D3PG)
    cacher="ddqn",          # long-timescale caching agent
    L=5,                    # denoising steps (paper Fig. 6a optimum)
    lr_actor=1e-4, lr_critic=1e-3, lr_ddqn=1e-3,  # CI-scale tuned lrs
    episodes=80,
)

# 2. train
ts, hist = train_t2drl(cfg, log_every=20)

# 3. greedy evaluation
ev = eval_t2drl(ts, cfg, episodes=5)
print("\n== greedy eval ==")
print(f"model hit ratio : {float(ev['hit_ratio']):.3f}")
print(f"total utility G : {float(ev['utility']):.2f}  (lower is better)")
print(f"mean slot reward: {float(ev['mean_reward']):.2f}")

# 4. compare against the random baseline in one line
rcars = T2DRLCfg(env=cfg.env, allocator="rcars", cacher="random")
from repro.core import t2drl_init
ev_r = eval_t2drl(t2drl_init(jax.random.PRNGKey(0), rcars), rcars,
                  episodes=5)
print(f"\nRCARS baseline  : hit {float(ev_r['hit_ratio']):.3f} "
      f"G {float(ev_r['utility']):.2f}")
print("T2DRL improves utility by "
      f"{100 * (1 - float(ev['utility']) / float(ev_r['utility'])):.1f}%")
