"""End-to-end driver: train a ~100M-param CompositeLM for a few hundred
steps on the synthetic learnable stream, with checkpointing.

This is the same train_step the multi-pod dry-run lowers for the production
mesh — here it runs for real on the local device at a ~100M scale.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax

from repro.launch.train import train_loop
from repro.models.lm import GroupCfg, LMCfg
from repro.models.blocks import BlockCfg
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MLPCfg


def make_100m():
    """~100M params: 12L, d_model=640, GQA 10/5 heads, d_ff=2560, 32k vocab."""
    blk = BlockCfg(d_model=640, mixer="attn", ffn="mlp",
                   attn=AttnCfg(640, 10, 5, 64, rope_theta=1e6),
                   mlp=MLPCfg(640, 2560))
    return LMCfg(name="lm-100m", vocab=32768, d_model=640,
                 groups=(GroupCfg((blk,), 12),))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    args = ap.parse_args()

    import repro.configs as C
    from repro.nn.core import count_params
    from repro.models.lm import lm_init

    cfg = make_100m()
    n = count_params(lm_init(jax.random.PRNGKey(0), cfg))
    print(f"model: {cfg.name}  params: {n / 1e6:.1f}M")

    # register as an ad-hoc arch for the generic train loop
    arch = C.Arch(name=cfg.name, family="dense", cite="(example)",
                  make_full=lambda **kw: cfg, make_smoke=lambda: cfg)
    import repro.launch.train as T
    sched = __import__("repro.optim", fromlist=["linear_warmup_cosine"])
    lrs = sched.linear_warmup_cosine(3e-4, warmup=30, steps=args.steps)
    init_fn, step_fn = T.make_train_fns(arch, cfg, lr_schedule=lrs)
    batch_fn = T.make_batch_fn(arch, cfg, batch=args.batch,
                               seq_len=args.seq_len)
    from repro.optim import adam_init
    key = jax.random.PRNGKey(0)
    params = init_fn(key)
    opt = adam_init(params)
    import time
    t0 = time.time()
    first = None
    for step in range(args.steps):
        b = batch_fn(jax.random.fold_in(key, step))
        params, opt, m = step_fn(params, opt, b)
        loss = float(m["loss"])
        first = first if first is not None else loss
        if (step + 1) % 25 == 0:
            tps = args.batch * args.seq_len * (step + 1) / (time.time() - t0)
            print(f"step {step + 1:4d}  loss {loss:7.4f}  "
                  f"({tps:,.0f} tok/s)", flush=True)
    print(f"\nloss: {first:.3f} -> {loss:.3f} over {args.steps} steps")
    from repro.checkpoint import bf16_safe_cast, save_pytree
    save_pytree("experiments/lm100m.msgpack", bf16_safe_cast(params))
    print("checkpoint saved to experiments/lm100m.msgpack")


if __name__ == "__main__":
    main()
