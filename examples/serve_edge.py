"""Edge AIGC gateway demo — the paper's full control loop against REAL
model execution (beyond-paper: the paper only models the edge analytically).

A trained T2DRL policy drives: DDQN picks which GenAI models the edge
caches each frame; D3PG splits bandwidth/compute each slot; the gateway
executes cached requests — diffusion image models run an actual DDPM
reverse chain with xi*L steps, LM models generate real tokens through the
continuous-batching engine.

  PYTHONPATH=src python examples/serve_edge.py [--frames 3 --slots 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import (EnvCfg, T2DRLCfg, actor_act, amend_caching,
                        amend_actions, ddqn_act, env_reset, make_actor_schedule,
                        make_models, observe, t2drl_init, train_t2drl)
from repro.core.env import env_new_frame, env_step_slot
from repro.models import lm as lm_mod
from repro.serving import CatalogEntry, EdgeGateway, Engine, ServeCfg
from repro.serving.gateway import toy_diffusion_builder


def build_catalogue(models, key):
    """M=6 GenAI models: 4 diffusion image models + 2 smoke LMs from the
    assigned-architecture pool."""
    cat = []
    for m in range(4):
        cat.append(CatalogEntry(
            model_id=m, name=f"repaint-{['faces','places','art','maps'][m]}",
            kind="diffusion", size_gb=float(models.c[m]),
            builder=toy_diffusion_builder(m, 64),
            a1=float(models.a1[m]), a2=float(models.a2[m]),
            a3=float(models.a3[m]), a4=float(models.a4[m]),
            b1=float(models.b1[m]), b2=float(models.b2[m])))

    def lm_builder(arch_name, seed):
        def build():
            cfg = get_arch(arch_name).make_smoke()
            params = lm_mod.lm_init(jax.random.PRNGKey(seed), cfg)
            return Engine(cfg, params, ServeCfg(max_batch=2, max_seq=128))
        return build

    for m, arch_name in ((4, "qwen2-0.5b"), (5, "mamba2-130m")):
        cat.append(CatalogEntry(
            model_id=m, name=f"{arch_name}-smoke", kind="lm",
            size_gb=float(models.c[m]), builder=lm_builder(arch_name, m),
            a1=float(models.a1[m]), a2=float(models.a2[m]),
            a3=float(models.a3[m]), a4=float(models.a4[m]),
            b1=float(models.b1[m]), b2=float(models.b2[m])))
    return cat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=3)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--train-episodes", type=int, default=30)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    env_cfg = EnvCfg(U=6, M=6, T=args.frames, K=args.slots, C=20.0)
    cfg = T2DRLCfg(env=env_cfg, lr_actor=1e-4, lr_critic=1e-3,
                   lr_ddqn=1e-3, episodes=args.train_episodes, warmup=20)

    print(f"training T2DRL policy ({args.train_episodes} episodes)...")
    ts, _ = train_t2drl(cfg)
    models = ts["models"]
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    sched = make_actor_schedule(d3)

    gw = EdgeGateway(build_catalogue(models, key), capacity_gb=env_cfg.C,
                     image_dim=64, total_steps=100)
    env = env_reset(key, env_cfg)

    for t in range(args.frames):
        kf = jax.random.fold_in(key, 1000 + t)
        a_int = ddqn_act(ts["ddqn"], dq, env.gamma_idx, kf, jnp.float32(0.0))
        rho = amend_caching(a_int, dq, models.c, env_cfg.C)
        env = env_new_frame(env, env_cfg, rho)
        info = gw.apply_caching(np.asarray(rho))
        print(f"\n== frame {t}: gamma={int(env.gamma_idx)} "
              f"cache={np.flatnonzero(np.asarray(rho)).tolist()} "
              f"loaded={sorted(gw.loaded)} used={info['used_gb']:.1f}GB "
              f"(load {info['load_s']:.2f}s)")
        for k in range(args.slots):
            ks = jax.random.fold_in(kf, k)
            s = observe(env, env_cfg, models)
            raw = actor_act(ts["d3pg"]["actor"], d3, sched, s, ks)
            b, xi = amend_actions(raw, env.req, env.rho, env_cfg.U)
            results = gw.serve_slot(np.asarray(env.req), np.asarray(xi), ks)
            env, r, m = env_step_slot(env, env_cfg, models, b, xi)
            served = sum(1 for x in results if x.cached)
            wall = sum(x.measured_wall_s for x in results)
            print(f"  slot {k}: reward {float(r):8.2f} "
                  f"hit {float(jnp.mean(m['cached'])):.2f} "
                  f"edge-served {served}/{env_cfg.U} "
                  f"(measured exec {wall:.2f}s, modeled "
                  f"{sum(x.modeled_delay for x in results):.1f}s)")
    print("\ndone — the paper's two-timescale control plane drove real "
          "model loading and execution end-to-end.")


if __name__ == "__main__":
    main()
