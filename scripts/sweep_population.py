"""Population-based hyperparameter sweep CLI (DESIGN.md §13).

  python scripts/sweep_population.py --smoke
  python scripts/sweep_population.py --episodes 120 --top 10
  python scripts/sweep_population.py --updates-per-slot 1,2

Thin CLI over ``benchmarks.bench_population`` (adds repo paths itself, so
no PYTHONPATH needed).  Trains the stock 16-member hyperparameter grid —
epsilon schedules x actor/critic LR x DDQN LR x reward shaping — as ONE
fused ``run_training`` call per static group (``--updates-per-slot`` with
N distinct values costs N compiles, crossing the grid to 16N members),
greedily evaluates every member, and prints the leaderboard against the
RCARS baseline.  Results land in ``experiments/bench/population.json``.

``--smoke`` is the CI preset: the full 16-member grid on a reduced
environment, asserting the whole sweep ran as one compiled call.
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="fused population-based hyperparameter sweep")
    ap.add_argument("--episodes", type=int, default=40,
                    help="training episodes per member (default 40)")
    ap.add_argument("--eval-episodes", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--top", type=int, default=8,
                    help="leaderboard rows to print")
    ap.add_argument("--updates-per-slot", default="1",
                    help="comma list of static updates_per_slot values; "
                         "each distinct value is its own compile group "
                         "(grid grows by the same factor)")
    ap.add_argument("--out", default="population.json",
                    help="output JSON name under experiments/bench/")
    ap.add_argument("--smoke", action="store_true",
                    help="CI preset: full 16-member grid, reduced env, "
                         "assert one compiled call")
    args = ap.parse_args()

    from benchmarks import bench_population
    from repro.core import default_grid

    ups = tuple(int(v) for v in args.updates_per_slot.split(","))
    grid = default_grid(updates_per_slot=ups)
    if args.smoke:
        if len(ups) != 1:
            ap.error("--smoke asserts a single compile group; drop "
                     "--updates-per-slot")
        bench_population.run_smoke()
        return
    bench_population.run(episodes=args.episodes,
                         eval_episodes=args.eval_episodes, grid=grid,
                         seed=args.seed, out_name=args.out, top=args.top)


if __name__ == "__main__":
    main()
