"""Scenario evaluation harness CLI — sweep workloads × methods.

  python scripts/eval_scenarios.py --scenarios all --methods t2drl,rcars \
      --num-envs 4

Thin CLI over ``benchmarks.bench_scenarios`` (adds repo paths itself, so no
PYTHONPATH needed).  Per-scenario reward/quality/latency breakdowns land in
experiments/bench/scenarios.json (schema in benchmarks/README.md).

Presets:

  --preset long-horizon   500-episode shared-learner run on the paper
                          workload (8 cells feeding one learner) — the
                          ROADMAP convergence open item: does T2DRL beat
                          RCARS once trained at the paper's episode count?
                          Uses the DESIGN.md §12 schedule levers (cosine
                          epsilon decay + cosine actor/critic LR warmdown
                          over 400 episodes); override with
                          --eps-schedule/--lr-schedule/--lr-warmdown-episodes.
  --smoke                 tiny CI-scale sweep (seconds, 2 cells): used by
                          the CI docs job and tests/test_scenarios.py.
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import EnvCfg                      # noqa: E402
from benchmarks import bench_scenarios             # noqa: E402

PRESETS = {
    # The ROADMAP convergence run, now with the schedule levers of
    # DESIGN.md §12: cosine epsilon decay (holds exploration longer before
    # annealing over the 300-episode eps horizon) and a cosine actor/critic
    # LR warmdown to 10% over 400 episodes, so late episodes fine-tune
    # instead of thrashing the shared learner.
    "long-horizon": dict(
        scenarios=["paper-default"], methods=["t2drl", "rcars"],
        episodes=500, eval_episodes=10, num_envs=8, policy="shared",
        out_name="scenarios_long_horizon.json",
        cfg_overrides=dict(eps_schedule="cosine", lr_schedule="cosine",
                           lr_warmdown_episodes=400, lr_end_scale=0.1)),
}


def main():
    ap = argparse.ArgumentParser(
        description="Sweep workload scenarios x methods; JSON breakdowns "
                    "to experiments/bench/.")
    ap.add_argument("--scenarios", default="all",
                    help="comma list of registered scenarios, or 'all'")
    ap.add_argument("--methods", default="t2drl,rcars",
                    help="comma list from t2drl,ddpg,schrs,rcars")
    ap.add_argument("--episodes", type=int, default=25,
                    help="training episodes for the learned methods")
    ap.add_argument("--eval-episodes", type=int, default=5)
    ap.add_argument("--num-envs", type=int, default=2,
                    help="parallel edge cells per scenario")
    ap.add_argument("--policy", default="shared",
                    choices=("independent", "shared"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--users", type=int, default=10, help="users per cell U")
    ap.add_argument("--models", type=int, default=10,
                    help="GenAI model types M")
    ap.add_argument("--frames", type=int, default=10,
                    help="frames per episode T")
    ap.add_argument("--slots", type=int, default=10, help="slots per frame K")
    ap.add_argument("--out", default="scenarios.json",
                    help="output file name under experiments/bench/ "
                         "(or $REPRO_BENCH_OUT)")
    # schedule flags default to None so an explicitly-passed flag can be
    # told apart from "unset" and win over a --preset's cfg_overrides
    ap.add_argument("--eps-schedule", default=None,
                    choices=("linear", "cosine"),
                    help="epsilon/sigma decay shape (T2DRLCfg.eps_schedule)")
    ap.add_argument("--lr-schedule", default=None,
                    choices=("const", "linear", "cosine"),
                    help="actor/critic LR warmdown shape")
    ap.add_argument("--lr-warmdown-episodes", type=int, default=None,
                    help="LR warmdown horizon in episodes")
    ap.add_argument("--lr-end-scale", type=float, default=None,
                    help="final LR as a fraction of the initial rate")
    ap.add_argument("--preset", choices=sorted(PRESETS),
                    help="named run configuration (overrides the non-"
                         "schedule flags it sets; explicit schedule flags "
                         "win over the preset's)")
    ap.add_argument("--obs-out", default=None,
                    help="JSONL telemetry log path; enables in-scan "
                         "learner diagnostics (DESIGN.md §15)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-scale sweep (overrides sizes/episodes)")
    args = ap.parse_args()

    flag_overrides = {k: v for k, v in dict(
        eps_schedule=args.eps_schedule, lr_schedule=args.lr_schedule,
        lr_warmdown_episodes=args.lr_warmdown_episodes,
        lr_end_scale=args.lr_end_scale).items() if v is not None}
    kw = dict(scenarios=args.scenarios.split(","),
              methods=args.methods.split(","), episodes=args.episodes,
              eval_episodes=args.eval_episodes, num_envs=args.num_envs,
              policy=args.policy, seed=args.seed, out_name=args.out,
              obs_out=args.obs_out,
              env=EnvCfg(U=args.users, M=args.models, T=args.frames,
                         K=args.slots))
    if args.preset:
        kw.update(PRESETS[args.preset])
    kw["cfg_overrides"] = {**kw.get("cfg_overrides", {}), **flag_overrides}
    if args.smoke:
        kw.update(episodes=2, eval_episodes=2, num_envs=2,
                  env=EnvCfg(U=4, M=4, T=3, K=3),
                  out_name="scenarios_smoke.json")
    bench_scenarios.run(**kw)


if __name__ == "__main__":
    main()
