"""Generate the §Dry-run and §Roofline markdown tables of EXPERIMENTS.md
from experiments/dryrun/*.json.

  PYTHONPATH=src python scripts/gen_experiments_tables.py > /tmp/tables.md
"""
import glob
import json
import os
import sys

GIB = 2 ** 30


def load(d="experiments/dryrun"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def fmt_bytes(b):
    return f"{b / GIB:.2f}"


def dryrun_table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh
            and r.get("opts", "base") == "base"]
    out = [f"| arch | shape | status | params | arg GiB/dev | tmp GiB/dev | "
           f"FLOPs/dev | coll bytes/dev | lower+compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order[r["shape"]]))
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP (see DESIGN.md) "
                       f"| | | | | | |")
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{r['n_params'] / 1e9:.2f}B | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} | "
            f"{r['roofline']['flops_per_chip']:.2e} | "
            f"{r['roofline']['coll_bytes_per_chip']:.2e} | "
            f"{r['lower_s'] + r['compile_s']:.0f} |")
    return "\n".join(out)


def roofline_table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh and r["status"] == "ok"
            and r.get("opts", "base") == "base"]
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order[r["shape"]]))
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL/HLO flops |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        ur = ro.get("useful_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"**{ro['bottleneck']}** | "
            f"{ur:.3f} |" if ur else
            f"| {r['arch']} | {r['shape']} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"**{ro['bottleneck']}** | - |")
    return "\n".join(out)


def perf_variants_table(recs):
    rows = [r for r in recs if r.get("opts", "base") != "base"
            and r["status"] == "ok"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["opts"]))
    out = ["| arch | shape | variant | compute s | memory s | collective s | "
           "arg GiB | tmp GiB |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        ro = r["roofline"]
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['opts']} | "
            f"{ro['compute_s']:.4f} | {ro['memory_s']:.4f} | "
            f"{ro['collective_s']:.4f} | "
            f"{fmt_bytes(m.get('argument_size_in_bytes', 0))} | "
            f"{fmt_bytes(m.get('temp_size_in_bytes', 0))} |")
    return "\n".join(out)


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    for mesh in ("pod16x16", "pod2x16x16"):
        n = sum(1 for r in recs if r["mesh"] == mesh)
        if not n:
            continue
        print(f"\n### Dry-run — {mesh}\n")
        print(dryrun_table(recs, mesh))
        print(f"\n### Roofline — {mesh}\n")
        print(roofline_table(recs, mesh))
    print("\n### Perf variants\n")
    print(perf_variants_table(recs))
