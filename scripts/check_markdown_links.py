"""Fail on broken intra-repo markdown links (CI docs job).

  python scripts/check_markdown_links.py [paths...]

Scans the given markdown files (default: every tracked/on-disk *.md
outside ignored dirs) for inline links/images ``[text](target)`` and
reference definitions ``[ref]: target``.  Relative targets must exist on
disk (anchors are stripped; ``#section`` anchors within the same file and
external ``http(s)/mailto`` targets are not checked).  Exit code 1 lists
every broken link as ``file:line: target``.
"""
from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".hypothesis",
             "experiments", "node_modules"}
# inline [text](target) — target up to the first unescaped ')' or space;
# images ![alt](target) match too via the optional bang.
INLINE = re.compile(r"!?\[[^\]\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REFDEF = re.compile(r"^\s{0,3}\[[^\]]+\]:\s+(\S+)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: str):
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for fn in filenames:
            if fn.endswith(".md"):
                yield os.path.join(dirpath, fn)


def check_file(path: str, root: str) -> list:
    broken = []
    with open(path, encoding="utf-8") as f:
        in_code = False
        for lineno, line in enumerate(f, 1):
            if line.lstrip().startswith("```"):
                in_code = not in_code
            if in_code:
                continue
            targets = INLINE.findall(line)
            m = REFDEF.match(line)
            if m:
                targets.append(m.group(1))
            for t in targets:
                t = t.strip("<>")
                if t.startswith(EXTERNAL) or t.startswith("#") or not t:
                    continue
                rel = t.split("#", 1)[0]
                if not rel:
                    continue
                base = root if rel.startswith("/") else os.path.dirname(path)
                if not os.path.exists(os.path.join(base, rel.lstrip("/"))):
                    broken.append((path, lineno, t))
    return broken


def main(argv) -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    paths = argv or sorted(md_files(root))
    broken = []
    for p in paths:
        broken += check_file(p, root)
    for path, lineno, target in broken:
        print(f"{os.path.relpath(path, root)}:{lineno}: broken link "
              f"-> {target}")
    if broken:
        print(f"\n{len(broken)} broken intra-repo link(s)")
        return 1
    print(f"checked {len(paths)} markdown file(s): all intra-repo links OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
