"""Fleet serving CLI — train → checkpoint → restore → replay request traffic.

  python scripts/serve_fleet.py --smoke
  python scripts/serve_fleet.py --scenarios all --methods t2drl,rcars \
      --episodes 60 --num-cells 4

Thin CLI over ``benchmarks.bench_fleet`` (adds repo paths itself, so no
PYTHONPATH needed).  Each method is trained on the paper-default workload,
checkpointed through ``repro.checkpoint.save_train_state``, restored, and
deployed in the request-level queueing twin (``repro.fleet``) under every
requested scenario's traffic trace.  Tail-latency / SLO / backlog metrics
land in experiments/bench/fleet.json (schema in benchmarks/README.md).

``--smoke`` is the CI gate: a tiny t2drl + rcars sweep over two scenarios
end-to-end from restored checkpoints, which FAILS (exit 1) unless the warm
jitted tick scan sustains at least 1e5 simulated requests/min.
"""
from __future__ import annotations

import argparse
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

from repro.core import EnvCfg                      # noqa: E402
from repro.fleet import FleetCfg                   # noqa: E402
from benchmarks import bench_fleet                 # noqa: E402

SMOKE_RATE_FLOOR = 1e5      # simulated requests/min, warm tick scan


def main():
    ap = argparse.ArgumentParser(
        description="Deploy checkpointed policies in the request-level "
                    "fleet twin; JSON metrics to experiments/bench/.")
    ap.add_argument("--scenarios", default="paper-default,flash-crowd",
                    help="comma list of registered scenarios, or 'all'")
    ap.add_argument("--methods", default="t2drl,rcars",
                    help="comma list from t2drl,ddpg,schrs,rcars")
    ap.add_argument("--episodes", type=int, default=25,
                    help="training episodes for the learned methods")
    ap.add_argument("--num-cells", type=int, default=2,
                    help="edge cells in the simulated fleet")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--users", type=int, default=10, help="users per cell U")
    ap.add_argument("--models", type=int, default=10,
                    help="GenAI model types M")
    ap.add_argument("--frames", type=int, default=10,
                    help="frames per episode T")
    ap.add_argument("--slots", type=int, default=10, help="slots per frame K")
    ap.add_argument("--ticks", type=int, default=20,
                    help="queue ticks per slot")
    ap.add_argument("--rate", type=float, default=0.01,
                    help="Poisson arrivals per active user per second")
    ap.add_argument("--slo", type=float, default=40.0,
                    help="end-to-end latency SLO (seconds)")
    ap.add_argument("--queue-cap", type=float, default=64.0,
                    help="per-(cell,model) queue capacity in requests")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default "
                         "<bench out>/ckpt)")
    ap.add_argument("--out", default="fleet.json",
                    help="output file name under experiments/bench/ "
                         "(or $REPRO_BENCH_OUT)")
    ap.add_argument("--obs-out", default=None,
                    help="JSONL telemetry log path; streams per-frame "
                         "fleet series (DESIGN.md §15)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI-scale sweep; asserts the sustained twin "
                         f"rate >= {SMOKE_RATE_FLOOR:.0e} requests/min")
    args = ap.parse_args()

    env = EnvCfg(U=args.users, M=args.models, T=args.frames, K=args.slots)
    fcfg = FleetCfg(ticks_per_slot=args.ticks,
                    arrivals_per_user_s=args.rate, slo=args.slo,
                    queue_cap=args.queue_cap)
    kw = dict(scenarios=args.scenarios.split(","),
              methods=args.methods.split(","), episodes=args.episodes,
              num_cells=args.num_cells, seed=args.seed, env=env, fcfg=fcfg,
              ckpt_dir=args.ckpt_dir, out_name=args.out,
              obs_out=args.obs_out)
    if args.smoke:
        print("--smoke: overriding scenario/method/size/rate flags with "
              "the CI preset")
        kw.update(scenarios=["paper-default", "flash-crowd"],
                  methods=["t2drl", "rcars"], episodes=2, num_cells=2,
                  env=EnvCfg(U=4, M=4, T=3, K=3),
                  fcfg=FleetCfg(ticks_per_slot=10, arrivals_per_user_s=1.0),
                  out_name="fleet_smoke.json")
    out = bench_fleet.run(**kw)
    if args.smoke:
        rate = out.get("sustained_requests_per_min", 0.0)
        if rate < SMOKE_RATE_FLOOR:
            print(f"FAIL: sustained twin rate {rate:.3g} req/min "
                  f"< {SMOKE_RATE_FLOOR:.0e}")
            raise SystemExit(1)
        print(f"smoke OK: {rate:.3g} simulated requests/min "
              f"(floor {SMOKE_RATE_FLOOR:.0e})")


if __name__ == "__main__":
    main()
