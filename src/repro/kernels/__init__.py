"""Pallas TPU kernels for the compute hot-spots (validated interpret=True on
CPU): flash_attention (prefill/train attention), ssd_scan (Mamba2 chunked
SSD), ddpm_step (fused D3PG reverse-diffusion update).  ``ops`` holds the
jit'd public wrappers; ``ref`` the pure-jnp oracles."""
from . import ops, ref  # noqa: F401
