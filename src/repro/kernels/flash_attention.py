"""Flash attention (causal GQA + optional sliding window) as a Pallas TPU
kernel.

TPU adaptation: online-softmax with the K/V sweep folded into the LAST grid
dimension — TPU grids execute sequentially over the trailing axis, so the
running (m, l, acc) state lives in VMEM scratch and persists across the
K-block iterations of one (batch, head, q-block) program.  Q/K blocks are
128-aligned for the MXU; softmax statistics are kept in f32 VREGs.

GQA is handled in the BlockSpec index maps (kv head = h // group) — no
materialised head repetition in HBM.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)        # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)        # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)        # (bk, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                        # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    # fully-masked rows (m_new == NEG_INF) must contribute nothing
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - m_new), 0.0)
    l_new = corr * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = corr * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhld(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None,
                         scale: Optional[float] = None, bq: int = 128,
                         bk: int = 128, interpret: bool = False):
    """q: (B, H, L, D); k/v: (B, Hkv, S, D).  Returns (B, H, L, D)."""
    B, H, L, D = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    bq = min(bq, L)
    bk = min(bk, S)
    assert L % bq == 0 and S % bk == 0, (L, bq, S, bk)
    nq, nk = L // bq, S // bk

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, L, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
