"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None, scale=None):
    """q: (B, L, H, D); k/v: (B, S, Hkv, D) with H % Hkv == 0.
    Returns (B, L, H, D) in q.dtype; softmax in f32."""
    B, L, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, L, Hkv, G, D)
    s = jnp.einsum("blkgd,bskd->bkgls", qg, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(L)[:, None]
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((L, S), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgls,bskd->blkgd", p, v.astype(jnp.float32))
    return o.reshape(B, L, H, D).astype(q.dtype)


def ssd_scan_ref(x, dt, A, Bm, Cm, D, *, chunk: int = 128):
    """Chunked-SSD oracle — delegates to the nn-layer reference (itself
    validated against a step-by-step recurrence in tests)."""
    from repro.nn.ssm import ssd_reference
    return ssd_reference(x, dt, A, Bm, Cm, D, chunk=chunk, return_state=True)


def ddpm_step_ref(x, eps_hat, noise, alpha, alpha_bar, beta_tilde, l_rev):
    """One fused reverse-diffusion update (Eqs. 19-20):
    mu = (x - (1-alpha)/sqrt(1-abar) * eps_hat)/sqrt(alpha);
    x' = mu + sqrt(beta_tilde)*noise  (noise suppressed at l_rev == 0)."""
    xf = x.astype(jnp.float32)
    mu = (xf - (1.0 - alpha) / jnp.sqrt(1.0 - alpha_bar)
          * eps_hat.astype(jnp.float32)) / jnp.sqrt(alpha)
    sigma = jnp.where(l_rev > 0, jnp.sqrt(beta_tilde), 0.0)
    return (mu + sigma * noise.astype(jnp.float32)).astype(x.dtype)
