"""Fused reverse-diffusion update (Eqs. 19-20) as a Pallas TPU kernel.

The D3PG actor's hot loop runs L of these per action sample; unfused it is
5 elementwise HLO ops with separate VMEM round-trips.  The kernel fuses

    x' = c1 * x - c2 * eps_hat + sigma * noise

where c1 = 1/sqrt(alpha_l), c2 = (1-alpha_l)/(sqrt(1-abar_l) sqrt(alpha_l)),
sigma = sqrt(beta_tilde_l) (0 at the last step) — the three per-step scalars
are precomputed on the host side of the scan and broadcast from a (1, 4)
coefficient row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ddpm_kernel(coef_ref, x_ref, eps_ref, noise_ref, o_ref):
    c1 = coef_ref[0, 0]
    c2 = coef_ref[0, 1]
    sigma = coef_ref[0, 2]
    x = x_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    noise = noise_ref[...].astype(jnp.float32)
    o_ref[...] = (c1 * x - c2 * eps + sigma * noise).astype(o_ref.dtype)


def ddpm_step_2d(x, eps_hat, noise, coef, *, block_rows: int = 256,
                 interpret: bool = False):
    """x/eps_hat/noise: (R, C) with C lane-aligned; coef: (1, 4) f32 row
    [c1, c2, sigma, 0].  Returns x' with x.dtype."""
    R, C = x.shape
    br = min(block_rows, R)
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        _ddpm_kernel,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((br, C), lambda i: (i, 0)),
            pl.BlockSpec((br, C), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(coef, x, eps_hat, noise)
