"""Mamba2 SSD (state-space duality) as a Pallas TPU kernel.

TPU adaptation of the CUDA warp-scan: one program per (batch, head, chunk);
the chunk axis is the LAST grid dimension, so the inter-chunk recurrent state
(P, N) lives in VMEM scratch and is carried across sequential grid steps.
Intra-chunk work is dense (Q,Q)/(Q,N)/(Q,P) matmuls on the MXU with f32
accumulation; Q defaults to 128 (lane-aligned).

Grouped B/C (G < H) is resolved in the BlockSpec index maps (g = h // rep),
mirroring the GQA trick in ``flash_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, s_out_ref,
                state_scr, *, Q: int, nc: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (Q,)
    A = a_ref[0].astype(jnp.float32)               # scalar (per head)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    D = d_ref[0].astype(jnp.float32)               # scalar

    a = dt * A                                     # (Q,) log-decay
    a_cs = jnp.cumsum(a)

    # intra-chunk: y[i] = sum_{j<=i} (C_i·B_j) exp(a_cs_i - a_cs_j) dt_j x_j
    seg = a_cs[:, None] - a_cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    m = cb * lmat * dt[None, :]
    y = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y[i] += C_i exp(a_cs_i) S_prev^T;  S_prev: (P, N)
    s_prev = state_scr[...]
    y = y + jax.lax.dot_general(Cm * jnp.exp(a_cs)[:, None], s_prev,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + D * x
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: S = S_prev * exp(a_cs[-1]) + x^T (B * decay_to_end * dt)
    decay_end = jnp.exp(a_cs[Q - 1] - a_cs)        # (Q,)
    bw = Bm * (decay_end * dt)[:, None]            # (Q, N)
    sc = jax.lax.dot_general(x, bw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    s_new = s_prev * jnp.exp(a_cs[Q - 1]) + sc
    state_scr[...] = s_new

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        s_out_ref[0, 0] = s_new


def ssd_scan_blhp(x, dt, A, Bm, Cm, D, *, chunk: int = 128,
                  interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); A/D: (H,); Bm/Cm: (B, L, G, N).
    L must be divisible by ``chunk``.  Returns (y (B,L,H,P) f32,
    final state (B,H,P,N) f32)."""
    Bsz, L, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, Q=Q, nc=nc)
    return pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1, Q, 1, N),
                         lambda b, h, c, rep=rep: (b, c, h // rep, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, L, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm, D)
