"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode — the kernel
body executes in Python for correctness validation; on TPU the same
``pallas_call`` lowers to Mosaic.  Layout/padding adaptation to the model
code's conventions happens here, never inside the kernels.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .ddpm_step import ddpm_step_2d
from .flash_attention import flash_attention_bhld
from .ssd_scan import ssd_scan_blhp


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, bq: int = 128,
                    bk: int = 128):
    """q: (B, L, H, D); k/v: (B, S, Hkv, D) — model-layer layout.  Pads L/S
    to block multiples (causal masking keeps padded K columns inert for real
    rows) and transposes to the kernel's (B, H, L, D)."""
    B, L, H, D = q.shape
    S = k.shape[1]
    bq_ = min(bq, max(8, L))
    bk_ = min(bk, max(8, S))
    Lp = -(-L // bq_) * bq_
    Sp = -(-S // bk_) * bk_
    qp = jnp.pad(q, ((0, 0), (0, Lp - L), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    o = flash_attention_bhld(
        qp.transpose(0, 2, 1, 3), kp.transpose(0, 2, 1, 3),
        vp.transpose(0, 2, 1, 3), causal=causal, window=window,
        bq=bq_, bk=bk_, interpret=_interpret())
    return o.transpose(0, 2, 1, 3)[:, :L]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128):
    """Mamba2 SSD.  x: (B, L, H, P); dt: (B, L, H); Bm/Cm: (B, L, G, N).
    Pads L with inert (dt = 0) steps.  Returns (y, final_state)."""
    B, L, H, P = x.shape
    Q = min(chunk, L)
    Lp = -(-L // Q) * Q
    if Lp != L:
        pad = Lp - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, s = ssd_scan_blhp(x.astype(jnp.float32), dt.astype(jnp.float32),
                         A.astype(jnp.float32), Bm.astype(jnp.float32),
                         Cm.astype(jnp.float32), D.astype(jnp.float32),
                         chunk=Q, interpret=_interpret())
    return y[:, :L], s


@jax.jit
def ddpm_step(x, eps_hat, noise, alpha, alpha_bar, beta_tilde, l_rev):
    """Fused reverse-diffusion update; x/eps_hat/noise: (..., A)."""
    c1 = 1.0 / jnp.sqrt(alpha)
    c2 = (1.0 - alpha) / (jnp.sqrt(1.0 - alpha_bar) * jnp.sqrt(alpha))
    sigma = jnp.where(l_rev > 0, jnp.sqrt(beta_tilde), 0.0)
    coef = jnp.stack([c1, c2, sigma, jnp.float32(0.0)]).astype(
        jnp.float32)[None, :]
    shape = x.shape
    A = shape[-1]
    R = max(1, x.size // A)
    Ap = -(-A // 128) * 128
    def pad2(a):
        a2 = a.reshape(R, A).astype(jnp.float32)
        return jnp.pad(a2, ((0, 0), (0, Ap - A)))
    o = ddpm_step_2d(pad2(x), pad2(eps_hat), pad2(noise), coef,
                     interpret=_interpret())
    return o[:, :A].reshape(shape).astype(x.dtype)
