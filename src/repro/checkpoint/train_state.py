"""RL train-state checkpointing (DESIGN.md §11: train → save → serve).

``save_train_state`` / ``load_train_state`` wrap the msgpack pytree codec
for the dict pytrees produced by ``repro.core.train_t2drl`` (and the policy
slices from ``export_policy``).  Two things the raw codec cannot do alone:

- ``ModelParams`` is a NamedTuple; the codec would round-trip it as a plain
  tuple and drop field access.  Known NamedTuple leaves are converted to
  tagged dicts on save and rebuilt on load, so a restored state is
  bit-identical *and* type-identical to the saved one.
- A checkpoint carries a small JSON-safe ``meta`` map (format version plus
  caller-supplied fields such as allocator/cacher/seed) so a serving
  process can sanity-check what it restored before deploying it.

The unified TrainState layout (DESIGN.md §12) keeps this codec agent-kind
agnostic: ``repro.core.t2drl_init`` always produces ``{"models", "d3pg",
"ddqn", "ebuf", "fbuf", "cache"}`` regardless of method (``"cache"`` is
the classical-cacher state machine, DESIGN.md §14), and ``export_policy``
delegates to ``Agent.export`` for the inference slice — so the same
save/restore path covers every allocator/cacher combination and both
vector-env modes without special cases (batched round-trip pinned in
``tests/test_fleet.py``).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import msgpack

from .msgpack_ckpt import _pack, _unpack

FORMAT_VERSION = 1
# NamedTuples are encoded as single-entry dicts {"__nt__:<Name>": fields};
# the tag rides in the *key* (map keys pass through the leaf codec verbatim,
# whereas a string value would be mangled into a unicode array).
_NT_TAG = "__nt__:"


def _nt_registry():
    # imported lazily: repro.core pulls in the whole agent stack, which the
    # LM-side checkpoint users of this package do not need at import time
    from repro.core import ModelParams
    return {"ModelParams": ModelParams}


def _encode(node):
    """Replace registered NamedTuples with tagged dicts (recursively)."""
    for name, cls in _nt_registry().items():
        if isinstance(node, cls):
            return {_NT_TAG + name: {k: _encode(v)
                                     for k, v in node._asdict().items()}}
    if isinstance(node, dict):
        return {k: _encode(v) for k, v in node.items()}
    if isinstance(node, tuple) and hasattr(node, "_fields"):
        # an unregistered NamedTuple would otherwise round-trip as a bare
        # tuple (losing field access) or crash the generic rebuild below
        raise TypeError(
            f"unregistered NamedTuple {type(node).__name__!r} in the "
            f"checkpoint tree; add it to train_state._nt_registry")
    if isinstance(node, (list, tuple)):
        return type(node)(_encode(v) for v in node)
    return node


def _decode(node):
    if isinstance(node, dict):
        if len(node) == 1:
            (key, fields), = node.items()
            if isinstance(key, str) and key.startswith(_NT_TAG):
                cls = _nt_registry()[key[len(_NT_TAG):]]
                return cls(**{k: _decode(v) for k, v in fields.items()})
        return {k: _decode(v) for k, v in node.items()}
    if isinstance(node, (list, tuple)):
        return type(node)(_decode(v) for v in node)
    return node


def save_train_state(path: str, ts: Any,
                     meta: Optional[Dict[str, Any]] = None) -> str:
    """Checkpoint a train-state (or policy) pytree to ``path``.

    Parameters
    ----------
    path : str
        Destination file (parent directories are created; the write is
        atomic via a same-directory temp file).
    ts : dict
        Any pytree of arrays/dicts/lists/tuples, including ``ModelParams``
        leaves — e.g. the state from ``train_t2drl`` or the policy from
        ``export_policy``.
    meta : dict, optional
        JSON-safe scalars/strings describing the run (allocator, cacher,
        seed, episodes, ...).  Stored next to the state and returned by
        ``load_train_state``.

    Returns
    -------
    str
        The path written.
    """
    payload = {"format": FORMAT_VERSION, "meta": dict(meta or {}),
               "state": _pack(_encode(ts))}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)
    return path


def load_train_state(path: str):
    """Restore a checkpoint written by ``save_train_state``.

    Returns
    -------
    (Any, dict)
        ``(state, meta)`` — the state pytree with NamedTuple leaves (e.g.
        ``ModelParams``) reconstructed, and the meta map saved alongside.
    """
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    fmt = payload.get("format")
    if fmt != FORMAT_VERSION:
        raise ValueError(f"unsupported train-state checkpoint format {fmt!r} "
                         f"(expected {FORMAT_VERSION}) in {path}")
    return _decode(_unpack(payload["state"])), payload.get("meta", {})
