from .msgpack_ckpt import bf16_safe_cast, load_pytree, save_pytree  # noqa: F401
from .train_state import load_train_state, save_train_state  # noqa: F401
