from .msgpack_ckpt import bf16_safe_cast, load_pytree, save_pytree  # noqa: F401
