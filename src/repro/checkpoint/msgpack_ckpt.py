"""msgpack pytree checkpointing with sharding-aware restore.

Leaves are stored as {dtype, shape, raw bytes}; the tree structure is
preserved as nested msgpack maps/lists.  ``load_pytree`` optionally takes a
``shardings`` pytree (NamedSharding per leaf) and device_puts each restored
leaf directly to its shards — no full-replica host copy per device.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_LEAF_KEY = "__leaf__"


def _pack(tree):
    if isinstance(tree, dict):
        return {k: _pack(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return {"__list__": [_pack(v) for v in tree],
                "__tuple__": isinstance(tree, tuple)}
    arr = np.asarray(tree)
    dtype = "bfloat16" if arr.dtype == jnp.bfloat16 else arr.dtype.str
    return {_LEAF_KEY: True, "dtype": dtype, "shape": list(arr.shape),
            "data": arr.tobytes()}


def _np_dtype(name: str):
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _unpack(node, shardings=None):
    if isinstance(node, dict) and node.get(_LEAF_KEY):
        arr = np.frombuffer(node["data"], dtype=_np_dtype(node["dtype"]))
        arr = arr.reshape(node["shape"])
        if shardings is not None:
            return jax.device_put(arr, shardings)
        return jnp.asarray(arr)
    if isinstance(node, dict) and "__list__" in node:
        shard_list = (shardings if isinstance(shardings, (list, tuple))
                      else [None] * len(node["__list__"]))
        vals = [_unpack(v, s) for v, s in zip(node["__list__"], shard_list)]
        return tuple(vals) if node.get("__tuple__") else vals
    if isinstance(node, dict):
        return {k: _unpack(v, shardings[k] if isinstance(shardings, dict)
                           else None)
                for k, v in node.items()}
    return node


def save_pytree(path: str, tree: Any) -> None:
    tree = jax.tree.map(np.asarray, tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(_pack(tree), use_bin_type=True))
    os.replace(tmp, path)


def load_pytree(path: str, *, shardings: Optional[Any] = None) -> Any:
    with open(path, "rb") as f:
        node = msgpack.unpackb(f.read(), raw=False, strict_map_key=False)
    return _unpack(node, shardings)


def bf16_safe_cast(tree):
    """numpy lacks bfloat16 — cast bf16 leaves to f32 on save."""
    return jax.tree.map(
        lambda a: a.astype(jnp.float32) if a.dtype == jnp.bfloat16 else a,
        tree)
