"""Built-in scenarios (DESIGN.md §9).

Each scenario targets a workload regime the paper's single stationary
setup cannot express — bursty user-diverse request patterns (cf.
arXiv:2301.03220) and heterogeneous edge resource profiles (cf.
arXiv:2409.05303) — while ``paper-default`` pins the original behavior
bit-for-bit (tests/test_scenarios.py).
"""
from __future__ import annotations

import dataclasses
import math

from .registry import ModSpec, Scenario, compose, register

register(Scenario(
    name="paper-default",
    summary="the paper's stationary Markov workload, bit-for-bit "
            "(identity transform, no modulation schedule)"))


register(Scenario(
    name="diurnal",
    summary="diurnal popularity rotation: the dominant Zipf-skewness state "
            "sweeps through the J states once per half-episode",
    mods=lambda s: dataclasses.replace(
        s, diurnal_period=5, diurnal_strength=0.8)))


register(Scenario(
    name="flash-crowd",
    summary="periodic flash crowds: every 10 slots, 3 slots where 85% of "
            "users pile onto one hot model with 1.5x input sizes",
    mods=lambda s: dataclasses.replace(
        s, burst_period=10, burst_width=3, burst_prob=0.85, burst_model=0,
        burst_din_scale=1.5)))


def _cycling_counts(cfg, num_envs):
    """Per-cell populations cycling U, 3U/4, U/2, U/4 (min 1 user)."""
    fracs = (1.0, 0.75, 0.5, 0.25)
    return tuple(max(1, math.ceil(cfg.U * fracs[b % len(fracs)]))
                 for b in range(num_envs))


register(Scenario(
    name="hetero-cells",
    summary="heterogeneous cells: per-cell user populations cycle "
            "U, 3U/4, U/2, U/4 over independent per-cell model zoos",
    user_counts=_cycling_counts))


register(Scenario(
    name="degraded-channel",
    summary="half the cells run with 10 dB worse channel gains "
            "(edge-of-coverage / interference-limited deployments)",
    mods=lambda s: dataclasses.replace(
        s, degraded_frac=0.5, degraded_h_scale=10.0 ** (-1.0))))


# Composition demo: the stressed regime every modulation hook is on at once.
register(compose(
    "rush-hour", "diurnal", "flash-crowd", "degraded-channel", "hetero-cells",
    summary="diurnal + flash-crowd + degraded-channel + hetero-cells "
            "stacked: the everything-at-once stress workload"))
