"""Scenario registry: named, composable workload transforms (DESIGN.md §9).

A *scenario* is a declarative recipe for a heterogeneous edge workload:

- a static transform over :class:`~repro.core.EnvCfg` (cell geometry,
  capacities, chain definitions — anything jit-static), plus
- a :class:`ModSpec` of time-varying modulation parameters, materialized
  once per build into a :class:`~repro.core.ScenarioSchedule` of
  precomputed arrays the env consumes at draw time (diurnal popularity
  rotation, flash-crowd bursts, degraded channels), plus
- optional per-cell user counts for heterogeneous populations.

Scenarios compose: each one is a transform over the (cfg, spec,
user_counts) triple, so ``compose("rush-hour", "diurnal", "flash-crowd")``
stacks modulations the same way the builtins do.  ``build_scenario`` turns
a name (or Scenario) into the arrays the training core takes directly::

    from repro.scenarios import build_scenario
    b = build_scenario("flash-crowd", cfg.env, num_envs=4)
    cfg = dataclasses.replace(cfg, env=b.env)
    ts, hist = train_t2drl(cfg, num_envs=4, mods=b.mods,
                           user_counts=b.user_counts)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import EnvCfg, ScenarioSchedule


@dataclasses.dataclass(frozen=True)
class ModSpec:
    """Plain-python modulation parameters, materialized by ``make_schedule``.

    All-default instances materialize to ``None`` (no schedule — the env
    runs its byte-identical unmodulated path), which is what makes the
    ``paper-default`` scenario an exact reproduction.

    Attributes
    ----------
    diurnal_period : int
        Frames per popularity-rotation cycle (0 = off).  Over each cycle
        the dominant popularity state sweeps through all J states.
    diurnal_strength : float
        Peak mixture weight of the rotated target chain in [0, 1].
    burst_period : int
        Slots between flash-crowd onsets (0 = off).
    burst_width : int
        Slots each flash crowd lasts.
    burst_prob : float
        Per-user probability of being redirected to the hot model during a
        burst.
    burst_model : int
        The hot model id requests are redirected to.
    burst_din_scale : float
        Input-size multiplier during a burst (crowds upload more).
    h_scale : float
        Homogeneous channel-gain multiplier (all cells, all slots).
    degraded_frac : float
        Fraction of cells whose channel is additionally degraded
        (cell-heterogeneous; the first ``ceil(frac*B)`` cells).
    degraded_h_scale : float
        Channel-gain multiplier applied to the degraded cells.
    """
    diurnal_period: int = 0
    diurnal_strength: float = 0.0
    burst_period: int = 0
    burst_width: int = 2
    burst_prob: float = 0.85
    burst_model: int = 0
    burst_din_scale: float = 1.0
    h_scale: float = 1.0
    degraded_frac: float = 0.0
    degraded_h_scale: float = 1.0

    def is_identity(self) -> bool:
        return self == ModSpec()


def _rotated_P(base: np.ndarray, spec: ModSpec, T: int) -> np.ndarray:
    """(T, J, J) frame-indexed popularity transitions: a convex mixture of
    the base chain and a 'push' chain whose dominant state rotates through
    the J states once per diurnal period."""
    J = base.shape[0]
    out = np.tile(base, (T, 1, 1))
    if not spec.diurnal_period or spec.diurnal_strength <= 0.0:
        return out
    for t in range(T):
        phase = (t % spec.diurnal_period) / spec.diurnal_period
        s = int(phase * J) % J
        push = np.full((J, J), 0.3 / J)
        push[:, s] += 0.7
        w = spec.diurnal_strength * 0.5 * (
            1.0 - math.cos(2.0 * math.pi * phase))
        out[t] = (1.0 - w) * base + w * push
    return out


def make_schedule(spec: ModSpec, cfg: EnvCfg,
                  num_envs: int = 1) -> Optional[ScenarioSchedule]:
    """Materialize a ModSpec into per-episode modulation arrays.

    Parameters
    ----------
    spec : ModSpec
        Modulation parameters (identity specs return ``None``).
    cfg : EnvCfg
        The (already scenario-transformed) env configuration; fixes the
        horizon ``T`` frames × ``K`` slots and the J popularity states.
    num_envs : int
        Cell count B.  Cell-heterogeneous specs (``degraded_frac > 0``)
        force per-cell leaves with a leading ``(B,)`` axis; homogeneous
        specs return unbatched leaves that the training API broadcasts.

    Returns
    -------
    ScenarioSchedule or None
        ``None`` iff the spec is the identity — callers then run the
        byte-identical unmodulated env path.
    """
    if spec.is_identity():
        return None
    T, K, J = cfg.T, cfg.K, len(cfg.gammas)
    S = T * K
    P = _rotated_P(np.asarray(cfg.P_gamma, np.float32), spec, T)
    h = np.full((S,), spec.h_scale, np.float32)
    din = np.ones((S,), np.float32)
    bp = np.zeros((S,), np.float32)
    if spec.burst_period:
        g = np.arange(S)
        in_burst = (g % spec.burst_period) < spec.burst_width
        bp[in_burst] = spec.burst_prob
        din[in_burst] *= spec.burst_din_scale
    sched = ScenarioSchedule(
        P_gamma=jnp.asarray(P), h_scale=jnp.asarray(h),
        din_scale=jnp.asarray(din), burst_prob=jnp.asarray(bp),
        burst_model=jnp.int32(min(spec.burst_model, cfg.M - 1)))
    if spec.degraded_frac > 0.0:
        n_bad = math.ceil(spec.degraded_frac * num_envs)
        cell_scale = np.ones((num_envs,), np.float32)
        cell_scale[:n_bad] = spec.degraded_h_scale
        sched = ScenarioSchedule(
            P_gamma=jnp.broadcast_to(sched.P_gamma, (num_envs, T, J, J)),
            h_scale=jnp.asarray(cell_scale[:, None] * h),
            din_scale=jnp.broadcast_to(sched.din_scale, (num_envs, S)),
            burst_prob=jnp.broadcast_to(sched.burst_prob, (num_envs, S)),
            burst_model=jnp.broadcast_to(sched.burst_model, (num_envs,)))
    return sched


def _id_env(cfg: EnvCfg) -> EnvCfg:
    return cfg


def _id_mods(spec: ModSpec) -> ModSpec:
    return spec


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, composable workload transform.

    Attributes
    ----------
    name : str
        Registry key (kebab-case).
    summary : str
        One-line description shown by ``list_scenarios``/the harness.
    env : callable
        ``EnvCfg -> EnvCfg`` static transform.
    mods : callable
        ``ModSpec -> ModSpec`` modulation transform (composable).
    user_counts : callable, optional
        ``(EnvCfg, num_envs) -> tuple[int, ...]`` per-cell active-user
        counts, or None for homogeneous full-population cells.
    """
    name: str
    summary: str
    env: Callable[[EnvCfg], EnvCfg] = _id_env
    mods: Callable[[ModSpec], ModSpec] = _id_mods
    user_counts: Optional[Callable[[EnvCfg, int], Tuple[int, ...]]] = None


@dataclasses.dataclass(frozen=True)
class ScenarioBuild:
    """Materialized scenario: everything the training/eval API consumes.

    Attributes
    ----------
    env : EnvCfg
        Transformed environment configuration (put into ``T2DRLCfg.env``).
    mods : ScenarioSchedule or None
        Modulation schedule for ``train_t2drl(..., mods=...)`` /
        ``eval_t2drl(..., mods=...)``; ``None`` = unmodulated env.
    user_counts : tuple of int, or None
        Per-cell user counts for heterogeneous populations.
    """
    env: EnvCfg
    mods: Optional[ScenarioSchedule]
    user_counts: Optional[Tuple[int, ...]]


_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    """Add a scenario to the registry (name must be unused)."""
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get_scenario(name: str) -> Scenario:
    """Look up a registered scenario by name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def list_scenarios() -> Dict[str, str]:
    """Registered scenario names -> one-line summaries (sorted)."""
    return {n: _REGISTRY[n].summary for n in sorted(_REGISTRY)}


def compose(name: str, *parts, summary: str = "") -> Scenario:
    """Stack scenarios left-to-right into a new (unregistered) Scenario.

    Env transforms and ModSpec transforms apply sequentially; the last
    part supplying ``user_counts`` wins.
    """
    parts = tuple(get_scenario(p) if isinstance(p, str) else p
                  for p in parts)

    def env(cfg: EnvCfg) -> EnvCfg:
        for p in parts:
            cfg = p.env(cfg)
        return cfg

    def mods(spec: ModSpec) -> ModSpec:
        for p in parts:
            spec = p.mods(spec)
        return spec

    counts = None
    for p in parts:
        if p.user_counts is not None:
            counts = p.user_counts
    return Scenario(name=name, summary=summary or " + ".join(
        p.name for p in parts), env=env, mods=mods, user_counts=counts)


def build_scenario(scenario, base_env: EnvCfg,
                   num_envs: int = 1) -> ScenarioBuild:
    """Materialize a scenario against a base EnvCfg for B cells.

    Parameters
    ----------
    scenario : str or Scenario
        Registry name or an (optionally composed) Scenario object.
    base_env : EnvCfg
        Starting configuration the scenario transforms.
    num_envs : int
        Cell count B the scenario will run under (fixes per-cell leaves
        and user-count tuples).

    Returns
    -------
    ScenarioBuild
        ``(env, mods, user_counts)`` ready for ``train_t2drl`` /
        ``eval_t2drl`` / the benchmark harness.
    """
    if isinstance(scenario, str):
        scenario = get_scenario(scenario)
    env = scenario.env(base_env)
    mods = make_schedule(scenario.mods(ModSpec()), env, num_envs)
    counts = (None if scenario.user_counts is None
              else tuple(scenario.user_counts(env, num_envs)))
    return ScenarioBuild(env=env, mods=mods, user_counts=counts)
