"""Config-driven scenario registry for heterogeneous edge workloads.

Public entry points (see DESIGN.md §9 for the modulation-hook contract):

- ``build_scenario(name, env_cfg, num_envs)`` — materialize a scenario
  into the ``ScenarioBuild(env, mods, user_counts)`` consumed by
  ``train_t2drl`` / ``eval_t2drl``.
- ``list_scenarios()`` / ``get_scenario(name)`` — inspect the registry.
- ``register(Scenario(...))`` / ``compose(name, *parts)`` — define new
  (possibly stacked) scenarios.
- ``ModSpec`` / ``make_schedule`` — the modulation parameters and their
  materializer, for scenarios defined from scratch.
"""
from .registry import (ModSpec, Scenario, ScenarioBuild,  # noqa: F401
                       build_scenario, compose, get_scenario,
                       list_scenarios, make_schedule, register)
from . import builtin  # noqa: F401  (registers the built-in scenarios)
