"""Fleet-scale request-level serving twin (DESIGN.md §11).

The training env scores a policy by *slot-averaged* analytic delay (paper
Eqs. 7-8); it cannot show queueing backlogs, p95/p99 tails, or SLO
violations — the metrics that decide whether an edge deployment survives
real traffic.  This module is the missing request-level lens: a fully
jitted queueing "digital twin" that replays a trained (checkpointed)
policy against Poisson request traffic and measures per-request latency.

Model:

- Each edge cell runs one FIFO queue per GenAI model.  A queue is a
  Lindley recursion over *unfinished work* ``W`` (seconds): an arrival
  with service time ``s`` entering a queue with backlog ``W`` waits ``W``
  seconds, so per-request latency decomposes exactly into
  ``queueing (W + (k-1)·s for the k-th same-tick arrival) + transmission
  (uplink + downlink) + compute (s)``.
- Service/transmission times per (cell, model) come from the *policy*:
  each slot the restored greedy policy allocates ``(b, xi)`` exactly as at
  training time; the env's ``slot_metrics`` maps that to per-user delays,
  which are averaged per requested model.  Models nobody requested in a
  slot keep their last observed service point (cloud-fallback estimate
  before first observation).
- Uncached models (``rho_m = 0``) take the cloud path: no edge queue
  (the cloud is capacity-unbounded here), latency = backhaul-inclusive
  transmission + cloud compute, exactly the paper's Sec. 3.4 fallback.
  Residual edge backlog of an evicted model keeps draining.
- Arrivals are Poisson per (cell, model, tick): total rate
  ``arrivals_per_user_s x active users``, split across models by the
  current popularity state's Zipf mix, reshaped by the scenario schedule
  (``burst_prob`` mass onto the hot model, ``din_scale`` as the offered-
  load multiplier, ``P_gamma`` drift, per-cell ``user_counts``) — every
  registered scenario is also a traffic trace.
- Metrics stream into fixed-bin latency histograms (scan-safe; quantiles
  are recovered host-side), plus SLO-violation / deadline-miss / drop
  counters and per-slot backlog curves.

Everything advances through a ``lax.scan`` over ticks nested in the slot
and frame scans, vmapped over cells — millions of simulated requests are
one compiled call, cheap even on a 2-core CPU host.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (T2DRLCfg, export_policy, greedy_frame_cache,
                        greedy_slot_action, make_user_masks, masked_mean)
from repro.core.env import (MB_BITS, env_advance_frame, env_reset,
                            env_set_cache, env_step_slot, radio_rates,
                            schedule_frame_P, schedule_slot_mod, zipf_logits)
from repro.core.quality import cloud_delay
from repro.core.t2drl import _batch_keys, _broadcast_mods


@dataclasses.dataclass(frozen=True)
class FleetCfg:
    """Static twin configuration (hashable -> jit-static).

    Attributes
    ----------
    ticks_per_slot : int
        Queue ticks per env slot; the tick duration is ``tau /
        ticks_per_slot`` seconds.  Arrivals/admissions/drains happen per
        tick, allocations per slot, caching per frame.
    arrivals_per_user_s : float
        Poisson request rate per active user (requests/second).
    max_arrivals : int
        Per-(cell, model, tick) arrival truncation bound (keeps the
        per-request latency expansion a fixed shape).  Truncated arrivals
        are counted in ``truncated``, never silently dropped.
    queue_cap : float
        Per-(cell, model) queue capacity in *requests* (backlog depth
        ``W/s``); arrivals beyond it are dropped and counted.
    slo : float
        End-to-end latency SLO (seconds) on queueing + transmission +
        compute.  The paper's deadline ``tau`` is still reported
        separately on the service-level delay (transmission + compute,
        no queueing) as ``deadline_miss`` — the twin's new information is
        exactly the gap between the two.
    hist_bins, hist_max : int, float
        Fixed latency histogram: ``hist_bins`` equal bins on
        ``[0, hist_max)`` seconds; the last bin absorbs overflow (a
        quantile landing there is reported as ``hist_max``).
    """
    ticks_per_slot: int = 20
    arrivals_per_user_s: float = 0.01
    max_arrivals: int = 8
    queue_cap: float = 64.0
    slo: float = 40.0
    hist_bins: int = 256
    hist_max: float = 240.0


def _zipf_mix(gamma_idx, cfg):
    """(M,) Zipf popularity mix of the current skewness state — the same
    Eq. (1) distribution the env samples requests from."""
    return jax.nn.softmax(zipf_logits(gamma_idx, cfg))


def _cell_episode(policy, tcfg: T2DRLCfg, fcfg: FleetCfg, models, key,
                  mask=None, mods=None):
    """One episode horizon of request-level serving for a single cell.

    Returns ``(counts, hist, curves, snaps)``: scalar counters, the
    (hist_bins,) latency histogram, per-slot ``{backlog, depth}`` curves
    of shape ``(T, K)``, and per-frame CUMULATIVE ``{counts, hist}``
    snapshots (leaves lead with ``(T,)``) — the host diffs consecutive
    snapshots into per-frame series (DESIGN.md §15), keeping the in-scan
    accumulation additive and allocation-free."""
    env_cfg = tcfg.env
    M, U = env_cfg.M, env_cfg.U
    dt = env_cfg.tau / fcfg.ticks_per_slot
    n_active = jnp.float32(U) if mask is None else jnp.sum(mask)
    A = fcfg.max_arrivals
    arange_k = jnp.arange(1, A + 1, dtype=jnp.float32)       # (A,)

    k_env, key = jax.random.split(key)
    env = env_reset(k_env, env_cfg, schedule_slot_mod(mods, 0))

    # cloud-fallback service point until a model is first observed: cloud
    # compute plus backhaul-inclusive transmission, with the radio legs
    # estimated at the equal bandwidth split (Eqs. 2/5 with b = 1/U) over
    # the reset slot's channel draws — the same rate model slot_metrics
    # applies to uncached users, so never-requested tail models are scored
    # on the full uplink + backhaul + downlink path, not backhaul alone
    d_in_mean = 0.5 * (env_cfg.d_in_mb[0] + env_cfg.d_in_mb[1]) * MB_BITS
    r_up0, r_dw0 = radio_rates(env.h, jnp.full((U,), 1.0 / U), env_cfg)
    qs0 = {"work": jnp.zeros(M),
           "serv": cloud_delay(models.a3, models.b1, models.b2),
           "trans": masked_mean(env.d_in / r_up0, mask)
           + d_in_mean / env_cfg.r_bc
           + models.d_op * (masked_mean(1.0 / r_dw0, mask)
                            + 1.0 / env_cfg.r_cb)}
    # request counters and histogram bins accumulate in int32 (exact up to
    # ~2.1e9 per cell per run — f32 would silently stop counting at ~2^24);
    # the latency/wait sums stay f32, they only feed means
    counts0 = {k: jnp.int32(0) for k in
               ("arrivals", "admitted", "dropped", "truncated", "slo_viol",
                "deadline_miss")}
    counts0.update(lat_sum=jnp.float32(0.0), wait_sum=jnp.float32(0.0))
    hist0 = jnp.zeros(fcfg.hist_bins, jnp.int32)

    def slot_step(carry, xs):
        k_slot, g = xs
        env, qs, counts, hist = carry
        ka, kt = jax.random.split(k_slot)
        b, xi = greedy_slot_action(policy, tcfg, env, models, ka, mask)
        env1, _, m = env_step_slot(env, env_cfg, models, b, xi, mask,
                                   schedule_slot_mod(mods, g + 1))
        # per-model service point observed from this slot's allocation
        w = jax.nn.one_hot(env.req, M)                        # (U, M)
        if mask is not None:
            w = w * mask[:, None]
        cnt = jnp.sum(w, axis=0)                              # (M,)
        safe = jnp.maximum(cnt, 1.0)
        serv = jnp.where(cnt > 0, (w.T @ m["delay_gt"]) / safe, qs["serv"])
        trans = jnp.where(cnt > 0,
                          (w.T @ (m["delay_up"] + m["delay_dw"])) / safe,
                          qs["trans"])
        # arrival mix for this slot: Zipf(gamma) reshaped by the scenario
        p = _zipf_mix(env.gamma_idx, env_cfg)
        rate_scale = jnp.float32(1.0)
        mod_g = schedule_slot_mod(mods, g)
        if mod_g is not None:
            p = ((1.0 - mod_g.burst_prob) * p
                 + mod_g.burst_prob * jax.nn.one_hot(mod_g.burst_model, M))
            rate_scale = mod_g.din_scale
        rate = (fcfg.arrivals_per_user_s * n_active * rate_scale * dt) * p
        cached = env.rho                                      # (M,) 0/1

        def tick(tick_carry, k_tick):
            work, counts, hist = tick_carry
            n_raw = jax.random.poisson(k_tick, rate).astype(jnp.float32)
            n = jnp.minimum(n_raw, float(A))
            depth = work / jnp.maximum(serv, 1e-6)
            room = jnp.floor(jnp.maximum(fcfg.queue_cap - depth, 0.0))
            adm = jnp.where(cached > 0, jnp.minimum(n, room), n)  # (M,)
            # k-th same-tick admission: queue wait work + (k-1)*serv
            valid = arange_k[None, :] <= adm[:, None]         # (M, A)
            wait = jnp.where(cached[:, None] > 0,
                             work[:, None] + (arange_k[None, :] - 1.0)
                             * serv[:, None], 0.0)
            lat = trans[:, None] + wait + serv[:, None]       # (M, A)
            v = valid.astype(jnp.float32)
            idx = jnp.clip((lat / fcfg.hist_max
                            * fcfg.hist_bins).astype(jnp.int32),
                           0, fcfg.hist_bins - 1)
            hist = hist.at[idx.ravel()].add(valid.astype(jnp.int32).ravel())
            d_service = trans + serv                          # no queueing
            i32 = lambda x: jnp.round(x).astype(jnp.int32)  # exact: x integral
            counts = {
                "arrivals": counts["arrivals"] + i32(jnp.sum(n)),
                "admitted": counts["admitted"] + i32(jnp.sum(adm)),
                "dropped": counts["dropped"]
                + i32(jnp.sum(jnp.where(cached > 0, n - adm, 0.0))),
                "truncated": counts["truncated"] + i32(jnp.sum(n_raw - n)),
                "slo_viol": counts["slo_viol"]
                + jnp.sum((valid & (lat > fcfg.slo)).astype(jnp.int32)),
                "deadline_miss": counts["deadline_miss"]
                + i32(jnp.sum(adm * (d_service > env_cfg.tau))),
                "lat_sum": counts["lat_sum"] + jnp.sum(v * lat),
                "wait_sum": counts["wait_sum"] + jnp.sum(v * wait),
            }
            work = jnp.maximum(
                work + jnp.where(cached > 0, adm * serv, 0.0) - dt, 0.0)
            return (work, counts, hist), None

        (work, counts, hist), _ = jax.lax.scan(
            tick, (qs["work"], counts, hist),
            jax.random.split(kt, fcfg.ticks_per_slot))
        qs = {"work": work, "serv": serv, "trans": trans}
        # depth: deepest single (cell, model) queue — the quantity the
        # per-queue queue_cap admission bound actually applies to
        ys = {"backlog": jnp.sum(work),
              "depth": jnp.max(work / jnp.maximum(serv, 1e-6))}
        return (env1, qs, counts, hist), ys

    def frame_step(carry, xs):
        k_frame, t = xs
        env, qs, counts, hist = carry
        kf = jax.random.split(k_frame, 3)
        env = env_advance_frame(env, env_cfg, schedule_frame_P(mods, t),
                                schedule_slot_mod(mods, t * env_cfg.K))
        rho = greedy_frame_cache(policy, tcfg, models, env.gamma_idx, kf[0])
        env = env_set_cache(env, rho)
        (env, qs, counts, hist), ys = jax.lax.scan(
            slot_step, (env, qs, counts, hist),
            (jax.random.split(kf[1], env_cfg.K),
             t * env_cfg.K + jnp.arange(env_cfg.K)))
        return (env, qs, counts, hist), (ys, {"counts": counts,
                                              "hist": hist})

    (_, qs, counts, hist), (curves, snaps) = jax.lax.scan(
        frame_step, (env, qs0, counts0, hist0),
        (jax.random.split(key, env_cfg.T), jnp.arange(env_cfg.T)))
    counts["end_backlog"] = jnp.sum(qs["work"])
    return counts, hist, curves, snaps


@functools.partial(jax.jit, static_argnames=("tcfg", "fcfg"))
def fleet_run(policy, models, tcfg: T2DRLCfg, fcfg: FleetCfg, keys,
              masks=None, mods=None):
    """Simulate one episode horizon for C cells (vmapped ``_cell_episode``).

    ``policy`` is shared across cells (deployment: one trained policy
    serves the fleet); ``models``/``keys``/``masks``/``mods`` carry a
    leading ``(C,)`` axis.  Returns per-cell ``(counts, hist, curves,
    snaps)``."""
    return jax.vmap(
        lambda mo, k, mk, md: _cell_episode(policy, tcfg, fcfg, mo, k,
                                            mask=mk, mods=md))(
        models, keys, masks, mods)


def latency_quantiles(hist, hist_max: float, qs: Sequence[float] = (0.5,
                      0.95, 0.99)):
    """Recover latency quantiles from a fixed-bin histogram (host-side).

    Linear interpolation inside the containing bin; a quantile landing in
    the overflow (last) bin is reported as ``hist_max``.  Returns
    ``{q: seconds}`` (NaN when the histogram is empty)."""
    hist = np.asarray(hist, np.float64)
    edges = np.linspace(0.0, hist_max, hist.size + 1)
    total = hist.sum()
    c = np.cumsum(hist)
    out = {}
    for q in qs:
        if total <= 0:
            out[q] = float("nan")
            continue
        target = q * total
        i = int(np.searchsorted(c, target))
        i = min(i, hist.size - 1)
        if i == hist.size - 1:
            out[q] = float(hist_max)
            continue
        prev = c[i - 1] if i > 0 else 0.0
        frac = (target - prev) / max(hist[i], 1e-12)
        out[q] = float(edges[i] + frac * (edges[i + 1] - edges[i]))
    return out


def simulate_fleet(ts, tcfg: T2DRLCfg, fcfg: FleetCfg = FleetCfg(), *,
                   num_cells: Optional[int] = None, seed: int = 0,
                   mods=None, user_counts: Optional[Sequence[int]] = None,
                   policy=None, cell: int = 0, writer=None, tags=None):
    """Deploy a trained (or restored) policy against request-level traffic.

    Parameters
    ----------
    ts : dict
        Train state from ``train_t2drl`` or ``repro.checkpoint.
        load_train_state`` — single or batched layout.  Only the model
        zoo and the inference parameters are used (``export_policy``).
    tcfg : T2DRLCfg
        The configuration the policy was trained under (allocator/cacher
        selection and the env the twin derives delays from).
    fcfg : FleetCfg
        Queueing-twin configuration.
    num_cells : int, optional
        Fleet size C.  An unbatched ``ts`` is replicated to C cells
        (same zoo, independent traffic); a batched ``ts`` fixes C to its
        own cell count.
    seed : int
        PRNG seed for traffic and policy sampling (cell keys follow the
        training-core ``_batch_keys`` convention).
    mods : ScenarioSchedule, optional
        Scenario schedule (``build_scenario(...).mods``) — the traffic
        trace.  Unbatched leaves broadcast to all cells.
    user_counts : sequence of int, optional
        Per-cell active-user populations (scales each cell's offered
        load and masks its allocations).
    policy : dict, optional
        Pre-exported policy pytree (skips ``export_policy``).
    cell : int
        Deployment is always ONE policy serving the whole fleet; for a
        batched *independent*-policy train state (B separate learners)
        this selects which cell's learner is deployed fleet-wide — the
        others are not consulted.  Ignored for shared-policy and
        unbatched states.
    writer : repro.obs.MetricWriter, optional
        Structured telemetry sink (DESIGN.md §15): one ``fleet_frame``
        record per frame (latency quantiles, drop / SLO-violation rates,
        mean backlog) plus a final ``fleet_summary``.  Purely host-side.
    tags : dict, optional
        Extra JSON-safe fields stamped on every emitted record (e.g.
        ``{"method": ..., "scenario": ...}``).

    Returns
    -------
    dict
        Fleet-level metrics: request counts and rates (``slo_viol_rate``,
        ``deadline_miss_rate``, ``drop_rate``), latency ``p50``/``p95``/
        ``p99`` + mean latency/wait, backlog stats and per-cell
        ``backlog_curve`` (C, T*K), the summed histogram, per-frame
        series under ``"frames"``, simulated seconds, wall seconds of
        this call and the derived ``requests_per_min`` (call twice and
        read the second for a compile-free sustained rate).
    """
    models = ts["models"]
    batched = models.a1.ndim == 2
    pol = export_policy(ts, tcfg, cell=cell) if policy is None else policy
    if batched:
        B = models.a1.shape[0]
        if num_cells is not None and num_cells != B:
            raise ValueError(f"ts is batched over {B} cells; "
                             f"num_cells={num_cells} does not match")
        num_cells = B
    else:
        num_cells = num_cells or 1
        models = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (num_cells,) + x.shape), models)
    masks = None
    if user_counts is not None:
        if len(user_counts) != num_cells:
            raise ValueError("user_counts must have one entry per cell")
        masks = make_user_masks(tcfg.env, user_counts)
    mods = _broadcast_mods(mods, num_cells)
    keys = _batch_keys(jax.random.PRNGKey(seed), num_cells)
    t0 = time.perf_counter()
    counts, hist, curves, snaps = jax.block_until_ready(
        fleet_run(pol, models, tcfg, fcfg, keys, masks, mods))
    wall = time.perf_counter() - t0
    out = summarize_fleet(counts, hist, curves, tcfg, fcfg, wall,
                          snaps=snaps)
    if writer is not None:
        tags = tags or {}
        writer.ensure_manifest(tcfg, extra={"fleet": dataclasses.asdict(fcfg),
                                            **tags})
        fr = out["frames"]
        for i in range(len(fr["frame"])):
            writer.write("fleet_frame",
                         **{k: v[i] for k, v in fr.items()}, **tags)
        skip = ("backlog_curve", "hist", "frames")
        writer.write("fleet_summary",
                     metrics={k: v for k, v in out.items()
                              if k not in skip}, **tags)
    return out


def _frame_series(snaps, curves, fcfg: FleetCfg):
    """Diff per-frame cumulative snapshots into fleet-level per-frame
    series (host-side NumPy).  ``snaps`` leaves lead with ``(C, T)``."""
    hist = np.asarray(snaps["hist"]).sum(axis=0)         # (T, bins) cumulative
    hist = np.diff(hist, axis=0, prepend=np.zeros((1, hist.shape[1])))
    cnt = {k: np.diff(np.asarray(v).sum(axis=0).astype(np.float64),
                      prepend=0.0)
           for k, v in snaps["counts"].items()}          # each (T,)
    backlog = np.asarray(curves["backlog"])              # (C, T, K)
    T = backlog.shape[1]
    out = {"frame": list(range(T)), "p50_s": [], "p95_s": [], "p99_s": [],
           "drop_rate": [], "slo_viol_rate": [], "mean_backlog_s": []}
    for t in range(T):
        q = latency_quantiles(hist[t], fcfg.hist_max)
        out["p50_s"].append(q[0.5])
        out["p95_s"].append(q[0.95])
        out["p99_s"].append(q[0.99])
        out["drop_rate"].append(
            float(cnt["dropped"][t] / max(cnt["arrivals"][t], 1.0)))
        out["slo_viol_rate"].append(
            float(cnt["slo_viol"][t] / max(cnt["admitted"][t], 1.0)))
        out["mean_backlog_s"].append(float(backlog[:, t].mean()))
    return out


def summarize_fleet(counts, hist, curves, tcfg: T2DRLCfg, fcfg: FleetCfg,
                    wall_s: float, snaps=None):
    """Reduce per-cell twin outputs to the fleet-level metric dict.  With
    ``snaps`` (per-frame cumulative snapshots from ``fleet_run``) the
    result additionally carries ``"frames"`` — per-frame latency
    quantiles, drop / SLO rates, and mean backlog series."""
    c = {k: float(np.sum(np.asarray(v))) for k, v in counts.items()}
    hist_all = np.sum(np.asarray(hist), axis=0)
    q = latency_quantiles(hist_all, fcfg.hist_max)
    backlog = np.asarray(curves["backlog"])          # (C, T, K)
    C = backlog.shape[0]
    flat_backlog = backlog.reshape(C, -1)
    depth = np.asarray(curves["depth"]).reshape(C, -1)
    adm = max(c["admitted"], 1.0)
    sim_s = tcfg.env.T * tcfg.env.K * tcfg.env.tau
    out = {
        "num_cells": C,
        "sim_seconds": float(sim_s),
        "requests": c["arrivals"],
        "admitted": c["admitted"],
        "dropped": c["dropped"],
        "truncated": c["truncated"],
        "drop_rate": c["dropped"] / max(c["arrivals"], 1.0),
        "slo_viol_rate": c["slo_viol"] / adm,
        "deadline_miss_rate": c["deadline_miss"] / adm,
        "mean_latency_s": c["lat_sum"] / adm,
        "mean_wait_s": c["wait_sum"] / adm,
        "p50_s": q[0.5], "p95_s": q[0.95], "p99_s": q[0.99],
        "end_backlog_s": c["end_backlog"],
        "mean_backlog_s": float(flat_backlog.mean()),
        "peak_backlog_s": float(flat_backlog.max()),
        "peak_queue_depth": float(depth.max()),
        "backlog_curve": flat_backlog,
        "hist": hist_all,
        "wall_s": wall_s,
        "requests_per_min": c["arrivals"] / max(wall_s, 1e-9) * 60.0,
    }
    if snaps is not None:
        out["frames"] = _frame_series(snaps, curves, fcfg)
    return out
