"""Request-level edge-fleet serving twin (DESIGN.md §11): jitted queueing
simulator with tail-latency SLOs, driven by checkpointed greedy policies."""
from .twin import (FleetCfg, fleet_run, latency_quantiles,  # noqa: F401
                   simulate_fleet, summarize_fleet)
