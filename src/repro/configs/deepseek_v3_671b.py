"""deepseek-v3-671b [moe] — 61L d_model=7168 128H, MLA (kv_lora=512),
MoE: 1 shared + 256 routed top-8, expert d_ff=2048, vocab=129280, MTP.
First 3 layers dense (d_ff=18432).  [arXiv:2412.19437]"""
from repro.configs import Arch
from repro.configs.common import deepseek_lm


def make_full(window=None, remat=False):
    return deepseek_lm("deepseek-v3-671b", layers=61, dense_layers=3,
                       d_model=7168, n_heads=128, vocab=129280,
                       moe_d_ff=2048, dense_d_ff=18432, n_experts=256,
                       top_k=8, n_shared=1, kv_lora_rank=512,
                       q_lora_rank=1536, mtp=True, window=window,
                       remat=remat)


def make_smoke():
    return deepseek_lm("deepseek-v3-671b-smoke", layers=2, dense_layers=1,
                       d_model=256, n_heads=4, vocab=512, moe_d_ff=128,
                       dense_d_ff=512, n_experts=4, top_k=2, n_shared=1,
                       kv_lora_rank=64, q_lora_rank=96, qk_nope_dim=32,
                       qk_rope_dim=16, v_head_dim=32, mtp=True)


ARCH = Arch(name="deepseek-v3-671b", family="moe", cite="arXiv:2412.19437",
            make_full=make_full, make_smoke=make_smoke)
