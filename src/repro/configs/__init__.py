"""Architecture registry: the 10 assigned architectures × 4 input shapes.

Each ``configs/<id>.py`` exports ``ARCH: Arch`` with the exact assigned
config (``make_full``) and a reduced same-family smoke variant
(``make_smoke``).  ``input_specs(arch, shape)`` returns weak-type-correct
``ShapeDtypeStruct`` stand-ins for every model input of the corresponding
step (train / prefill / decode) — no device allocation, as used by the
multi-pod dry-run.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}

# window applied to attention archs for the sub-quadratic long_500k variant
LONG_CONTEXT_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    family: str                      # dense|moe|hybrid|ssm|audio|vlm
    cite: str
    make_full: Callable[..., Any]    # kwargs: window, remat
    make_smoke: Callable[[], Any]
    kind: str = "lm"                 # "lm" | "whisper"
    n_prefix: int = 0                # VLM vision slots
    prefix_embed_dim: int = 0        # VLM raw patch dim
    needs_window_for_long: bool = True   # False for ssm/hybrid (native)
    supports_long: bool = True       # whisper: False (see DESIGN.md)


ARCH_IDS = [
    "qwen2_0_5b", "olmo_1b", "codeqwen1_5_7b", "deepseek_v3_671b",
    "zamba2_7b", "deepseek_v2_236b", "mamba2_130m", "whisper_small",
    "internvl2_2b", "qwen3_4b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update({
    "qwen2-0.5b": "qwen2_0_5b", "olmo-1b": "olmo_1b",
    "codeqwen1.5-7b": "codeqwen1_5_7b", "deepseek-v3-671b": "deepseek_v3_671b",
    "zamba2-7b": "zamba2_7b", "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-130m": "mamba2_130m", "whisper-small": "whisper_small",
    "internvl2-2b": "internvl2_2b", "qwen3-4b": "qwen3_4b",
})


def canonical_id(name: str) -> str:
    """'qwen2-0.5b' -> 'qwen2_0_5b' (the module id used in filenames)."""
    return _ALIASES.get(name, name)


def get_arch(name: str) -> Arch:
    mod_name = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.ARCH


def list_archs():
    return [get_arch(i) for i in ARCH_IDS]


def supports(arch: Arch, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and not arch.supports_long:
        return False, ("decoder uses learned absolute positions capped at "
                       "448 in the source model; a 524k decoder context has "
                       "no meaningful analogue (DESIGN.md §Shape skips)")
    return True, ""


def make_cfg(arch: Arch, shape: str, *, remat: Optional[bool] = None,
             unroll: bool = False):
    """Model config for (arch, shape): applies the sliding-window variant for
    attention-family archs on long_500k, remat for training shapes.
    ``unroll=True`` python-unrolls layer stacks (dry-run cost accounting)."""
    kw = {}
    if shape == "long_500k" and arch.needs_window_for_long:
        kw["window"] = LONG_CONTEXT_WINDOW
    if remat is None:
        remat = SHAPES[shape].step == "train"
    kw["remat"] = remat
    cfg = arch.make_full(**kw)
    if unroll:
        cfg = dataclasses.replace(cfg, unroll=True)
    return cfg


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: Arch, shape: str, *, cache_dtype=jnp.bfloat16):
    """Returns (step, inputs: dict[str, pytree-of-ShapeDtypeStruct]).

    train:   {tokens, labels[, prefix_embeds | frame_embeds]}
    prefill: {tokens[, prefix_embeds | frame_embeds], cache}
    decode:  {token, cache, pos}
    """
    sc = SHAPES[shape]
    cfg = make_cfg(arch, shape)
    B, L = sc.global_batch, sc.seq_len
    step = sc.step

    if arch.kind == "whisper":
        from repro.models.whisper import whisper_init_cache
        fe = _sds((B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
        if step == "train":
            return step, {"frame_embeds": fe,
                          "tokens": _sds((B, L), jnp.int32),
                          "labels": _sds((B, L), jnp.int32)}
        cache = jax.eval_shape(
            lambda: whisper_init_cache(cfg, B, L, dtype=cache_dtype))
        if step == "prefill":
            return step, {"frame_embeds": fe,
                          "tokens": _sds((B, L), jnp.int32), "cache": cache}
        return step, {"token": _sds((B, 1), jnp.int32), "cache": cache,
                      "pos": _sds((), jnp.int32)}

    from repro.models.lm import lm_init_cache
    n_pre = arch.n_prefix
    if step == "train":
        d = {"tokens": _sds((B, L - n_pre), jnp.int32),
             "labels": _sds((B, L), jnp.int32)}
        if n_pre:
            d["prefix_embeds"] = _sds((B, n_pre, arch.prefix_embed_dim),
                                      jnp.bfloat16)
        return step, d
    if step == "prefill":
        cache = jax.eval_shape(
            lambda: lm_init_cache(cfg, B, L, dtype=cache_dtype))
        d = {"tokens": _sds((B, L - n_pre), jnp.int32), "cache": cache}
        if n_pre:
            d["prefix_embeds"] = _sds((B, n_pre, arch.prefix_embed_dim),
                                      jnp.bfloat16)
        return step, d
    cache = jax.eval_shape(lambda: lm_init_cache(cfg, B, L, dtype=cache_dtype))
    return step, {"token": _sds((B, 1), jnp.int32), "cache": cache,
                  "pos": _sds((), jnp.int32)}
