"""internvl2-2b [vlm] — InternViT frontend STUBBED (precomputed patch
embeddings, 256 × 1024 per image); LM backbone = InternLM2-1.8B:
24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  [arXiv:2404.16821]"""
from repro.configs import Arch
from repro.configs.common import dense_lm

N_PREFIX = 256          # patch slots per image (448px / 14 / pixel-shuffle 2)
PATCH_DIM = 1024        # InternViT-300M hidden size


def make_full(window=None, remat=False):
    return dense_lm("internvl2-2b", layers=24, d_model=2048, n_heads=16,
                    n_kv_heads=8, d_ff=8192, vocab=92553, tie=False,
                    window=window, remat=remat, n_prefix=N_PREFIX,
                    prefix_embed_dim=PATCH_DIM)


def make_smoke():
    return dense_lm("internvl2-2b-smoke", layers=2, d_model=128, n_heads=4,
                    n_kv_heads=2, d_ff=256, vocab=512, tie=False,
                    n_prefix=8, prefix_embed_dim=64)


ARCH = Arch(name="internvl2-2b", family="vlm", cite="arXiv:2404.16821",
            make_full=make_full, make_smoke=make_smoke, n_prefix=N_PREFIX,
            prefix_embed_dim=PATCH_DIM)
