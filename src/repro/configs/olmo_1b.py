"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304;
non-parametric LayerNorm (no scale/bias).  [arXiv:2402.00838]"""
from repro.configs import Arch
from repro.configs.common import dense_lm


def make_full(window=None, remat=False):
    return dense_lm("olmo-1b", layers=16, d_model=2048, n_heads=16,
                    n_kv_heads=16, d_ff=8192, vocab=50304, norm="ln_np",
                    tie=True, window=window, remat=remat)


def make_smoke():
    return dense_lm("olmo-1b-smoke", layers=2, d_model=128, n_heads=4,
                    n_kv_heads=4, d_ff=256, vocab=512, norm="ln_np", tie=True)


ARCH = Arch(name="olmo-1b", family="dense", cite="arXiv:2402.00838",
            make_full=make_full, make_smoke=make_smoke)
