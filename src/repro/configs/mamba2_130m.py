"""mamba2-130m [ssm] — 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality).  [arXiv:2405.21060]"""
from repro.configs import Arch
from repro.configs.common import mamba_lm


def make_full(window=None, remat=False):
    del window  # attention-free: long_500k is native
    return mamba_lm("mamba2-130m", layers=24, d_model=768, d_state=128,
                    vocab=50280, head_dim=64, n_groups=1, remat=remat)


def make_smoke():
    return mamba_lm("mamba2-130m-smoke", layers=2, d_model=128, d_state=32,
                    vocab=512, head_dim=32, chunk=16)


ARCH = Arch(name="mamba2-130m", family="ssm", cite="arXiv:2405.21060",
            make_full=make_full, make_smoke=make_smoke,
            needs_window_for_long=False)
