"""qwen3-4b [dense] — 36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936; qk-norm, no QKV bias, head_dim=128.  [hf:Qwen/Qwen3-8B]"""
from repro.configs import Arch
from repro.configs.common import dense_lm


def make_full(window=None, remat=False):
    return dense_lm("qwen3-4b", layers=36, d_model=2560, n_heads=32,
                    n_kv_heads=8, d_ff=9728, vocab=151936, d_head=128,
                    qk_norm=True, rope_theta=1e6, tie=True, window=window,
                    remat=remat)


def make_smoke():
    return dense_lm("qwen3-4b-smoke", layers=2, d_model=128, n_heads=4,
                    n_kv_heads=2, d_ff=256, vocab=512, d_head=32,
                    qk_norm=True, tie=True)


ARCH = Arch(name="qwen3-4b", family="dense", cite="hf:Qwen/Qwen3-8B",
            make_full=make_full, make_smoke=make_smoke)
