"""zamba2-7b [hybrid] — 81L d_model=3584, Mamba2 (ssm_state=64) + SHARED
attention blocks (32H kv=32, d_ff=14336) interleaved every 6th position:
13 × (5 Mamba2 + 1 shared attn) + 3 Mamba2 tail = 81.  [arXiv:2411.15242]"""
from repro.configs import Arch
from repro.configs.common import zamba_lm


def make_full(window=None, remat=False):
    del window  # hybrid runs long_500k natively (attn share is windowless
    # but only 13/81 layers; the SSM majority keeps state constant-size)
    return zamba_lm("zamba2-7b", mamba_per_cycle=5, cycles=13, tail_mamba=3,
                    d_model=3584, d_state=64, n_heads=32, n_kv_heads=32,
                    d_ff=14336, vocab=32000, remat=remat)


def make_smoke():
    return zamba_lm("zamba2-7b-smoke", mamba_per_cycle=2, cycles=1,
                    tail_mamba=1, d_model=128, d_state=16, n_heads=4,
                    n_kv_heads=4, d_ff=256, vocab=512, head_dim=32,
                    n_groups=1, chunk=16)


ARCH = Arch(name="zamba2-7b", family="hybrid", cite="arXiv:2411.15242",
            make_full=make_full, make_smoke=make_smoke,
            needs_window_for_long=False)
