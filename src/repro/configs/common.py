"""Shared builders for assigned-architecture configs."""
from __future__ import annotations

from typing import Optional, Tuple

from repro.models.blocks import BlockCfg
from repro.models.lm import GroupCfg, LMCfg
from repro.nn.attention import AttnCfg
from repro.nn.mla import MLACfg
from repro.nn.mlp import MLPCfg
from repro.nn.moe import MoECfg
from repro.nn.ssm import SSMCfg


def dense_lm(name: str, *, layers: int, d_model: int, n_heads: int,
             n_kv_heads: int, d_ff: int, vocab: int,
             d_head: Optional[int] = None, qkv_bias: bool = False,
             qk_norm: bool = False, norm: str = "rms",
             rope_theta: float = 10000.0, tie: bool = True,
             window: Optional[int] = None, remat: bool = False,
             n_prefix: int = 0, prefix_embed_dim: int = 0) -> LMCfg:
    d_head = d_head or d_model // n_heads
    blk = BlockCfg(
        d_model=d_model, mixer="attn", ffn="mlp", norm=norm,
        attn=AttnCfg(d_model, n_heads, n_kv_heads, d_head, qkv_bias=qkv_bias,
                     qk_norm=qk_norm, rope_theta=rope_theta, window=window),
        mlp=MLPCfg(d_model, d_ff))
    return LMCfg(name=name, vocab=vocab, d_model=d_model,
                 groups=(GroupCfg((blk,), layers),),
                 final_norm="rms" if norm == "rms" else "ln_np",
                 tie_embeddings=tie, remat=remat, n_prefix=n_prefix,
                 prefix_embed_dim=prefix_embed_dim)


def deepseek_lm(name: str, *, layers: int, dense_layers: int, d_model: int,
                n_heads: int, vocab: int, moe_d_ff: int, dense_d_ff: int,
                n_experts: int, top_k: int, n_shared: int,
                kv_lora_rank: int = 512, q_lora_rank: int = 1536,
                qk_nope_dim: int = 128, qk_rope_dim: int = 64,
                v_head_dim: int = 128, mtp: bool = False,
                window: Optional[int] = None, remat: bool = False,
                capacity_factor: float = 1.25) -> LMCfg:
    mla = MLACfg(d_model, n_heads, q_lora_rank=q_lora_rank,
                 kv_lora_rank=kv_lora_rank, qk_nope_dim=qk_nope_dim,
                 qk_rope_dim=qk_rope_dim, v_head_dim=v_head_dim,
                 window=window)
    dense_blk = BlockCfg(d_model=d_model, mixer="mla", ffn="mlp", mla=mla,
                         mlp=MLPCfg(d_model, dense_d_ff))
    moe_blk = BlockCfg(d_model=d_model, mixer="mla", ffn="moe", mla=mla,
                       moe=MoECfg(d_model, moe_d_ff, n_experts=n_experts,
                                  top_k=top_k, n_shared=n_shared,
                                  capacity_factor=capacity_factor))
    return LMCfg(name=name, vocab=vocab, d_model=d_model,
                 groups=(GroupCfg((dense_blk,), dense_layers),
                         GroupCfg((moe_blk,), layers - dense_layers)),
                 tie_embeddings=False, mtp=mtp, remat=remat)


def mamba_lm(name: str, *, layers: int, d_model: int, d_state: int,
             vocab: int, head_dim: int = 64, n_groups: int = 1,
             expand: int = 2, chunk: int = 128, remat: bool = False) -> LMCfg:
    blk = BlockCfg(
        d_model=d_model, mixer="ssm", ffn="none",
        ssm=SSMCfg(d_model, expand * d_model, head_dim=head_dim,
                   n_groups=n_groups, d_state=d_state, chunk=chunk))
    return LMCfg(name=name, vocab=vocab, d_model=d_model,
                 groups=(GroupCfg((blk,), layers),), tie_embeddings=True,
                 remat=remat)


def zamba_lm(name: str, *, mamba_per_cycle: int, cycles: int,
             tail_mamba: int, d_model: int, d_state: int, n_heads: int,
             n_kv_heads: int, d_ff: int, vocab: int, head_dim: int = 64,
             n_groups: int = 2, chunk: int = 128,
             remat: bool = False) -> LMCfg:
    """Zamba2-style hybrid: cycles of (mamba_per_cycle × Mamba2 + 1 shared
    attention block) followed by a tail of Mamba2 blocks.  The attention
    block's parameters are SHARED across cycle repeats (Zamba2's signature
    trick); its KV caches remain per-occurrence."""
    ssm = SSMCfg(d_model, 2 * d_model, head_dim=head_dim, n_groups=n_groups,
                 d_state=d_state, chunk=chunk)
    m_blk = BlockCfg(d_model=d_model, mixer="ssm", ffn="none", ssm=ssm)
    a_blk = BlockCfg(
        d_model=d_model, mixer="attn", ffn="mlp", shared=True,
        attn=AttnCfg(d_model, n_heads, n_kv_heads, d_model // n_heads),
        mlp=MLPCfg(d_model, d_ff))
    groups = [GroupCfg((m_blk,) * mamba_per_cycle + (a_blk,), cycles)]
    if tail_mamba:
        groups.append(GroupCfg((m_blk,), tail_mamba))
    return LMCfg(name=name, vocab=vocab, d_model=d_model,
                 groups=tuple(groups), tie_embeddings=True, remat=remat)
