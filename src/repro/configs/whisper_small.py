"""whisper-small [audio] — enc-dec, 12L each, d_model=768 12H d_ff=3072
vocab=51865.  Conv/mel frontend STUBBED: encoder consumes precomputed frame
embeddings (B, 1500, 768) per the assignment carve-out.  long_500k is
SKIPPED (learned absolute decoder positions, 448-token spec cap — see
DESIGN.md §Shape skips).  [arXiv:2212.04356]"""
from repro.configs import Arch
from repro.models.whisper import WhisperCfg


def make_full(window=None, remat=False):
    del window
    return WhisperCfg(name="whisper-small", vocab=51865, d_model=768,
                      n_layers=12, n_heads=12, d_ff=3072, n_frames=1500,
                      max_positions=32768, remat=remat)


def make_smoke():
    return WhisperCfg(name="whisper-small-smoke", vocab=512, d_model=128,
                      n_layers=2, n_heads=4, d_ff=256, n_frames=30,
                      max_positions=128)


ARCH = Arch(name="whisper-small", family="audio", cite="arXiv:2212.04356",
            make_full=make_full, make_smoke=make_smoke, kind="whisper",
            supports_long=False, needs_window_for_long=False)
