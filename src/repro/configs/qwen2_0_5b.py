"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936; GQA with QKV bias.  [arXiv:2407.10671]"""
from repro.configs import Arch
from repro.configs.common import dense_lm


def make_full(window=None, remat=False):
    return dense_lm("qwen2-0.5b", layers=24, d_model=896, n_heads=14,
                    n_kv_heads=2, d_ff=4864, vocab=151936, qkv_bias=True,
                    rope_theta=1e6, tie=True, window=window, remat=remat)


def make_smoke():
    return dense_lm("qwen2-0.5b-smoke", layers=2, d_model=128, n_heads=4,
                    n_kv_heads=2, d_ff=256, vocab=512, qkv_bias=True,
                    rope_theta=1e6, tie=True)


ARCH = Arch(name="qwen2-0.5b", family="dense", cite="arXiv:2407.10671",
            make_full=make_full, make_smoke=make_smoke)
