"""deepseek-v2-236b [moe] — 60L d_model=5120 128H, MLA kv_lora=512,
MoE: 2 shared + 160 routed top-6, expert d_ff=1536, vocab=102400.
First layer dense (d_ff=12288).  [arXiv:2405.04434]"""
from repro.configs import Arch
from repro.configs.common import deepseek_lm


def make_full(window=None, remat=False):
    return deepseek_lm("deepseek-v2-236b", layers=60, dense_layers=1,
                       d_model=5120, n_heads=128, vocab=102400,
                       moe_d_ff=1536, dense_d_ff=12288, n_experts=160,
                       top_k=6, n_shared=2, kv_lora_rank=512,
                       q_lora_rank=1536, window=window, remat=remat)


def make_smoke():
    return deepseek_lm("deepseek-v2-236b-smoke", layers=2, dense_layers=1,
                       d_model=256, n_heads=4, vocab=512, moe_d_ff=128,
                       dense_d_ff=512, n_experts=4, top_k=2, n_shared=2,
                       kv_lora_rank=64, q_lora_rank=96, qk_nope_dim=32,
                       qk_rope_dim=16, v_head_dim=32)


ARCH = Arch(name="deepseek-v2-236b", family="moe", cite="arXiv:2405.04434",
            make_full=make_full, make_smoke=make_smoke)
