"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (kv=32) d_ff=13440
vocab=92416; qwen1.5 arch (QKV bias).  [hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs import Arch
from repro.configs.common import dense_lm


def make_full(window=None, remat=False):
    return dense_lm("codeqwen1.5-7b", layers=32, d_model=4096, n_heads=32,
                    n_kv_heads=32, d_ff=13440, vocab=92416, qkv_bias=True,
                    rope_theta=1e6, tie=False, window=window, remat=remat)


def make_smoke():
    return dense_lm("codeqwen1.5-7b-smoke", layers=2, d_model=128, n_heads=4,
                    n_kv_heads=4, d_ff=320, vocab=512, qkv_bias=True,
                    tie=False)


ARCH = Arch(name="codeqwen1.5-7b", family="dense",
            cite="hf:Qwen/CodeQwen1.5-7B", make_full=make_full,
            make_smoke=make_smoke)
