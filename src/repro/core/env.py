"""AIGC edge-service environment (paper Secs. 3-4), fully jittable.

State evolves on two timescales: per-frame (AIGC popularity skewness gamma,
a J-state Markov chain; caching decision rho held fixed) and per-slot (user
location distribution lambda, an I-state Markov chain; Rayleigh fading drawn
i.i.d.; per-user requests ~ Zipf(gamma)).

All of Eqs. (1)-(10) and the reward (23) are implemented exactly; physical
constants follow Table 2.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .quality import gen_delay, tv_quality

MB_BITS = 8e6  # bits per MB


@dataclasses.dataclass(frozen=True)
class EnvCfg:
    """Static environment configuration (paper Table 2), hashable → jit-static.

    Scenario transforms produce new ``EnvCfg`` instances via
    ``dataclasses.replace``; anything *time-varying* instead lives in a
    ``ScenarioSchedule`` consumed at draw time (DESIGN.md §9).

    Attributes
    ----------
    U, M : int
        Number of users / GenAI model types in the cell.
    T, K : int
        Frames per episode (long timescale) and slots per frame (short).
    tau : float
        Slot duration in seconds — also the service deadline (11h).
    L_steps : float
        Total denoising steps available at the BS per slot.
    C : float
        BS model-cache capacity (GB), constraint (11d).
    W_up, W_dw : float
        Shared uplink / per-user downlink bandwidth (Hz).
    p_user_dbm, p_bs_dbm, n0_dbm_hz : float
        Transmit powers and noise PSD (dBm / dBm/Hz).
    r_bc, r_cb : float
        BS↔cloud backhaul rates (bps) for uncached requests.
    d_in_mb, d_op_mb : tuple of float
        Uniform ranges for input/output sizes (MB).
    alpha, chi, Xi : float
        Delay-vs-quality weight (10), deadline penalty (23), storage
        penalty (32).
    area : float
        Cell square side (m); the BS sits at the center.
    gammas : tuple of float
        Zipf skewness values of the J popularity states.
    P_gamma, P_lambda : tuple of tuple of float
        Markov transition matrices for popularity (37) and user-location
        distribution (36).
    """
    U: int = 10                 # users
    M: int = 10                 # GenAI model types
    T: int = 10                 # frames per episode
    K: int = 10                 # slots per frame
    tau: float = 20.0           # slot duration (s) = deadline (11h)
    L_steps: float = 1000.0     # total denoising steps at the BS
    C: float = 20.0             # BS storage capacity (GB)
    W_up: float = 20e6          # uplink bandwidth (Hz), shared
    W_dw: float = 40e6          # per-user downlink bandwidth (Hz)
    p_user_dbm: float = 23.0
    p_bs_dbm: float = 43.0
    n0_dbm_hz: float = -176.0   # noise PSD (dBm/Hz)
    r_bc: float = 100e6         # BS->cloud backhaul (bps)
    r_cb: float = 100e6         # cloud->BS backhaul (bps)
    d_in_mb: Tuple[float, float] = (5.0, 10.0)
    d_op_mb: Tuple[float, float] = (5.0, 10.0)
    alpha: float = 0.7          # delay-vs-quality preference (10)
    chi: float = 10.0           # deadline penalty (23)
    Xi: float = 100.0           # storage penalty (32)
    area: float = 250.0         # square side (m)
    gammas: Tuple[float, ...] = (0.2, 0.5, 0.7)     # J popularity states
    # Eq. (37) popularity transitions
    P_gamma: Tuple[Tuple[float, ...], ...] = (
        (0.6, 0.2, 0.2), (0.1, 0.7, 0.2), (0.2, 0.3, 0.5))
    # Eq. (36) location-distribution transitions
    P_lambda: Tuple[Tuple[float, ...], ...] = (
        (0.6, 0.1, 0.3), (0.3, 0.6, 0.1), (0.1, 0.3, 0.6))

    @property
    def p_user(self) -> float:          # mW
        return 10 ** (self.p_user_dbm / 10)

    @property
    def p_bs(self) -> float:            # mW
        return 10 ** (self.p_bs_dbm / 10)

    @property
    def n0(self) -> float:              # mW/Hz
        return 10 ** (self.n0_dbm_hz / 10)

    @property
    def state_dim(self) -> int:         # Eq. (21): 4U + M
        return 4 * self.U + self.M

    @property
    def action_dim(self) -> int:        # Eq. (22): 2U
        return 2 * self.U


class ModelParams(NamedTuple):
    """Per-GenAI-model fitted curve + storage parameters (Sec. 7.1)."""
    a1: jnp.ndarray   # (M,) steps where quality starts improving  [50,100]
    a2: jnp.ndarray   # (M,) worst TV                               [100,150]
    a3: jnp.ndarray   # (M,) steps where quality saturates          [150,200]
    a4: jnp.ndarray   # (M,) best TV                                (0,50]
    b1: jnp.ndarray   # (M,) delay slope                            (0,0.5]
    b2: jnp.ndarray   # (M,) delay intercept                        (0,10]
    c: jnp.ndarray    # (M,) storage (GB)                           [2,10]
    d_op: jnp.ndarray  # (M,) output size (bits)


def make_models(key, cfg: EnvCfg) -> ModelParams:
    ks = jax.random.split(key, 8)
    u = lambda k, lo, hi: jax.random.uniform(k, (cfg.M,), minval=lo, maxval=hi)
    return ModelParams(
        a1=u(ks[0], 50.0, 100.0), a2=u(ks[1], 100.0, 150.0),
        a3=u(ks[2], 150.0, 200.0), a4=u(ks[3], 1.0, 50.0),
        b1=u(ks[4], 0.05, 0.5), b2=u(ks[5], 1.0, 10.0),
        c=u(ks[6], 2.0, 10.0),
        d_op=u(ks[7], cfg.d_op_mb[0], cfg.d_op_mb[1]) * MB_BITS)


def make_models_batch(keys, cfg: EnvCfg) -> ModelParams:
    """Independent model zoos for B edge cells: every leaf gains a leading
    (B,) axis.  keys: (B, 2) PRNG keys, one per cell."""
    return jax.vmap(lambda k: make_models(k, cfg))(keys)


class EnvState(NamedTuple):
    key: jnp.ndarray
    gamma_idx: jnp.ndarray    # () int32 — popularity state (per frame)
    lambda_idx: jnp.ndarray   # () int32 — location state (per slot)
    pos: jnp.ndarray          # (U, 2) user positions (m)
    h: jnp.ndarray            # (U,) channel gains (linear)
    req: jnp.ndarray          # (U,) int32 requested model ids
    d_in: jnp.ndarray         # (U,) input sizes (bits)
    rho: jnp.ndarray          # (M,) float 0/1 caching decision


# -- scenario modulation (DESIGN.md §9) ---------------------------------------
#
# A scenario supplies time-varying modulation of the env's draw distributions
# as a ScenarioSchedule: precomputed arrays indexed by frame t (``P_gamma``)
# or by the global slot index g = t*K + k (the per-slot leaves).  The env
# consumes one SlotMod slice per draw.  ``mod=None`` everywhere takes the
# unmodulated code path — the PRNG stream and arithmetic are byte-identical
# to the paper-default env, which is what pins the ``paper-default``
# scenario (tests/test_scenarios.py).


class SlotMod(NamedTuple):
    """Per-slot modulation consumed by the env at draw time.

    All leaves are scalars (or ``(B,)`` under a leading cell batch):
    ``h_scale`` multiplies the drawn channel gains, ``din_scale`` the drawn
    input sizes, and with probability ``burst_prob`` each user's Zipf draw
    is redirected to the flash-crowd model ``burst_model``.
    """
    h_scale: jnp.ndarray      # () channel-gain multiplier
    din_scale: jnp.ndarray    # () input-size multiplier
    burst_prob: jnp.ndarray   # () per-user redirect probability
    burst_model: jnp.ndarray  # () int32 flash-crowd model id


class ScenarioSchedule(NamedTuple):
    """One episode worth of modulation, fully precomputed (jit/scan-safe).

    Leaves are plain arrays so a schedule can be closed over, scanned, and
    vmapped; a leading ``(B,)`` cell axis on every leaf gives per-cell
    schedules (heterogeneous scenarios under the vectorized core).
    """
    P_gamma: jnp.ndarray      # (T, J, J) frame-indexed popularity transitions
    h_scale: jnp.ndarray      # (T*K,) per-slot channel-gain multiplier
    din_scale: jnp.ndarray    # (T*K,) per-slot input-size multiplier
    burst_prob: jnp.ndarray   # (T*K,) per-slot flash-crowd redirect prob
    burst_model: jnp.ndarray  # () int32 flash-crowd model id


def schedule_slot_mod(sched: ScenarioSchedule | None, g) -> SlotMod | None:
    """Slice the SlotMod for global slot ``g`` (clamped to the horizon).

    Works on both unbatched ``(T*K,)`` and cell-batched ``(B, T*K)``
    schedules; ``sched=None`` passes through (unmodulated env).
    """
    if sched is None:
        return None
    g = jnp.minimum(g, sched.h_scale.shape[-1] - 1)
    return SlotMod(h_scale=sched.h_scale[..., g],
                   din_scale=sched.din_scale[..., g],
                   burst_prob=sched.burst_prob[..., g],
                   burst_model=sched.burst_model)


def schedule_frame_P(sched: ScenarioSchedule | None, t):
    """Popularity transition matrix for frame ``t`` (or None = cfg default)."""
    if sched is None:
        return None
    return sched.P_gamma[..., t, :, :]


def _apply_burst(key, req, mod: SlotMod):
    """Redirect each user's request to the hot model w.p. burst_prob."""
    redirect = jax.random.uniform(key, req.shape) < mod.burst_prob
    return jnp.where(redirect, mod.burst_model.astype(req.dtype), req)


# -- sampling -----------------------------------------------------------------

def _sample_positions(key, lambda_idx, cfg: EnvCfg):
    """lambda states: 0 uniform, 1 concentrated (around BS), 2 boundary."""
    k1, k2, k3 = jax.random.split(key, 3)
    A = cfg.area
    uni = jax.random.uniform(k1, (cfg.U, 2), minval=0.0, maxval=A)
    conc = jnp.clip(A / 2 + 30.0 * jax.random.normal(k2, (cfg.U, 2)), 0.0, A)
    edge = jax.random.uniform(k3, (cfg.U, 2), minval=0.0, maxval=A)
    side = jax.random.randint(jax.random.fold_in(k3, 1), (cfg.U,), 0, 4)
    off = jax.random.uniform(jax.random.fold_in(k3, 2), (cfg.U,),
                             minval=0.0, maxval=15.0)
    bx = jnp.where(side == 0, off, jnp.where(side == 1, A - off, edge[:, 0]))
    by = jnp.where(side == 2, off, jnp.where(side == 3, A - off, edge[:, 1]))
    bnd = jnp.stack([bx, by], axis=-1)
    return jnp.where(lambda_idx == 0, uni,
                     jnp.where(lambda_idx == 1, conc, bnd))


def _channel_gain(key, pos, cfg: EnvCfg):
    """h = g·|delta|^2, path loss Eq. (3) (distance in km), Rayleigh fading."""
    bs = jnp.array([cfg.area / 2, cfg.area / 2])
    dis_km = jnp.maximum(
        jnp.linalg.norm(pos - bs, axis=-1), 1.0) / 1000.0
    g_db = -128.1 - 37.6 * jnp.log10(dis_km)
    g = 10.0 ** (g_db / 10.0)
    rayleigh2 = jax.random.exponential(key, (pos.shape[0],))  # |CN(0,1)|^2
    return g * rayleigh2


def zipf_logits(gamma_idx, cfg: EnvCfg):
    """Unnormalized log-weights of the Eq. (1) Zipf popularity over model
    ids for skewness state ``gamma_idx`` — the single source of truth for
    both the env's request sampler and the fleet twin's arrival mix."""
    gamma = jnp.asarray(cfg.gammas)[gamma_idx]
    ranks = jnp.arange(1, cfg.M + 1, dtype=jnp.float32)
    return -gamma * jnp.log(ranks)


def _sample_requests(key, gamma_idx, cfg: EnvCfg):
    """Zipf over model ids, Eq. (1)."""
    return jax.random.categorical(key, zipf_logits(gamma_idx, cfg),
                                  shape=(cfg.U,))


def _sample_markov(key, idx, P):
    return jax.random.categorical(key, jnp.log(jnp.asarray(P)[idx] + 1e-12))


def _refresh_slot(key, state: EnvState, cfg: EnvCfg,
                  new_lambda: bool = True, mod: SlotMod | None = None
                  ) -> EnvState:
    """Draw per-slot randomness: location state, positions, fading,
    requests, input sizes.  ``mod`` (a SlotMod for the slot being drawn)
    scales the channel gains / input sizes and redirects a burst fraction
    of requests; ``mod=None`` is the exact unmodulated draw (same PRNG
    splits, same arithmetic)."""
    if mod is None:
        kl, kp, kh, kr, kd, knext = jax.random.split(key, 6)
    else:
        kl, kp, kh, kr, kd, kb, knext = jax.random.split(key, 7)
    lam = (_sample_markov(kl, state.lambda_idx, cfg.P_lambda)
           if new_lambda else state.lambda_idx)
    pos = _sample_positions(kp, lam, cfg)
    h = _channel_gain(kh, pos, cfg)
    req = _sample_requests(kr, state.gamma_idx, cfg)
    d_in = jax.random.uniform(kd, (cfg.U,), minval=cfg.d_in_mb[0],
                              maxval=cfg.d_in_mb[1]) * MB_BITS
    if mod is not None:
        h = h * mod.h_scale
        d_in = d_in * mod.din_scale
        req = _apply_burst(kb, req, mod)
    return EnvState(key=knext, gamma_idx=state.gamma_idx, lambda_idx=lam,
                    pos=pos, h=h, req=req, d_in=d_in, rho=state.rho)


def env_reset(key, cfg: EnvCfg, mod: SlotMod | None = None) -> EnvState:
    """Draw the initial env state (slot 0 randomness included).

    Parameters
    ----------
    key : jax.random.PRNGKey
        Episode reset key.
    cfg : EnvCfg
        Static environment configuration.
    mod : SlotMod, optional
        Scenario modulation for the first slot's draws (``None`` = the
        unmodulated paper-default env).

    Returns
    -------
    EnvState
        Initial state with positions/fading/requests for slot 0 drawn.
    """
    kg, kl, ks = jax.random.split(key, 3)
    st = EnvState(
        key=ks,
        gamma_idx=jax.random.randint(kg, (), 0, len(cfg.gammas)),
        lambda_idx=jax.random.randint(kl, (), 0, len(cfg.P_lambda)),
        pos=jnp.zeros((cfg.U, 2)), h=jnp.ones((cfg.U,)),
        req=jnp.zeros((cfg.U,), jnp.int32),
        d_in=jnp.ones((cfg.U,)) * cfg.d_in_mb[0] * MB_BITS,
        rho=jnp.zeros((cfg.M,)))
    k, knext = jax.random.split(st.key)
    return _refresh_slot(k, st._replace(key=knext), cfg, new_lambda=False,
                         mod=mod)


def env_reset_batch(keys, cfg: EnvCfg, mod: SlotMod | None = None) -> EnvState:
    """Reset B independent cells; every EnvState leaf gains a leading (B,)
    axis.  Cells share the static EnvCfg but evolve their own popularity /
    location Markov chains from independent initial states.  ``mod``:
    optional per-cell SlotMod with (B,) leaves."""
    return jax.vmap(lambda k, m: env_reset(k, cfg, m))(keys, mod)


def make_user_masks(cfg: EnvCfg, counts) -> jnp.ndarray:
    """(B, U) float masks for heterogeneous per-cell user counts.

    ``counts[b]`` users are active in cell b (the first ``counts[b]`` of the
    U slots); inactive users receive zero allocation, contribute nothing to
    the reward, and are zeroed in the observation.  This is how cells with
    different populations share one compiled, batched program."""
    counts = jnp.asarray(counts)
    return (jnp.arange(cfg.U)[None, :] < counts[:, None]).astype(jnp.float32)


def env_advance_frame(state: EnvState, cfg: EnvCfg, P_gamma=None,
                      mod: SlotMod | None = None) -> EnvState:
    """Frame boundary: popularity Markov transition; requests for the first
    slot of the new frame are re-drawn under the new skewness.  The caching
    decision for the frame is applied afterwards via ``env_set_cache`` —
    Algorithm 1 observes s(t) = {gamma(t)} *before* choosing rho(t).

    ``P_gamma`` overrides the popularity transition matrix for this frame
    (diurnal scenarios pass ``schedule_frame_P(sched, t)``); ``mod`` applies
    the flash-crowd redirect to the re-drawn requests.  Both default to the
    unmodulated paper-default behavior (identical PRNG stream)."""
    if mod is None:
        k, kr, knext = jax.random.split(state.key, 3)
    else:
        k, kr, kb, knext = jax.random.split(state.key, 4)
    P = cfg.P_gamma if P_gamma is None else P_gamma
    gamma = _sample_markov(k, state.gamma_idx, P)
    req = _sample_requests(kr, gamma, cfg)
    if mod is not None:
        req = _apply_burst(kb, req, mod)
    return state._replace(key=knext, gamma_idx=gamma, req=req)


def env_set_cache(state: EnvState, rho) -> EnvState:
    return state._replace(rho=rho)


def env_new_frame(state: EnvState, cfg: EnvCfg, rho, P_gamma=None,
                  mod: SlotMod | None = None) -> EnvState:
    """Frame boundary: popularity Markov transition + new caching decision.

    Accepts the same frame-indexed schedule slices as
    ``env_advance_frame`` (``P_gamma`` transition override, ``mod`` burst
    redirect) so external drivers (e.g. ``examples/serve_edge.py``) can run
    any registered scenario."""
    return env_set_cache(env_advance_frame(state, cfg, P_gamma, mod), rho)


# -- slot dynamics (Eqs. 2-10, 23) --------------------------------------------

def radio_rates(h, b, cfg: EnvCfg):
    """Eqs. (2)/(5): per-user uplink rate under bandwidth shares ``b`` and
    the (share-independent) downlink rate, for channel gains ``h``.  The
    single source of truth for the radio model — used by ``slot_metrics``
    and by the fleet twin's pre-observation service estimates."""
    snr_up = cfg.p_user * h / (cfg.n0 * b * cfg.W_up)
    r_up = b * cfg.W_up * jnp.log2(1.0 + snr_up)
    snr_dw = cfg.p_bs * h / (cfg.n0 * cfg.W_dw)
    r_dw = cfg.W_dw * jnp.log2(1.0 + snr_dw)
    return r_up, r_dw


def slot_metrics(state: EnvState, cfg: EnvCfg, models: ModelParams, b, xi):
    """Compute per-user delay/quality/utility for allocation (b, xi)."""
    cached = state.rho[state.req]                      # (U,) 0/1
    b = jnp.maximum(b, 1e-9)
    r_up, r_dw = radio_rates(state.h, b, cfg)
    # Eq. (4): upload delay (+ backhaul if not cached)
    d_up = state.d_in / r_up + (1.0 - cached) * state.d_in / cfg.r_bc
    d_op = models.d_op[state.req]
    # Eq. (6): feedback delay
    d_dw = d_op / r_dw + (1.0 - cached) * d_op / cfg.r_cb
    # Eqs. (7)-(8): generation quality / delay
    steps = xi * cfg.L_steps
    m = state.req
    q_edge = tv_quality(steps, models.a1[m], models.a2[m], models.a3[m],
                        models.a4[m])
    q = jnp.where(cached > 0, q_edge, models.a4[m])
    d_gt_edge = gen_delay(steps, models.b1[m], models.b2[m])
    d_gt_cloud = models.b1[m] * models.a3[m] + models.b2[m]
    d_gt = jnp.where(cached > 0, d_gt_edge, d_gt_cloud)
    # Eq. (9)-(10)
    d_tl = d_up + d_dw + d_gt
    G = cfg.alpha * d_tl + (1.0 - cfg.alpha) * q
    return {"G": G, "d_tl": d_tl, "quality": q, "delay_up": d_up,
            "delay_dw": d_dw, "delay_gt": d_gt, "cached": cached,
            "rate_up": r_up, "rate_dw": r_dw}


def masked_mean(x, mask=None):
    """Mean over the user axis; with a (U,) 0/1 mask, mean over active
    users only (safe when no user is active)."""
    if mask is None:
        return jnp.mean(x)
    return jnp.sum(x * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def slot_reward(metrics, cfg: EnvCfg, mask=None):
    """Eq. (23); with ``mask`` the per-user costs of inactive users are
    excluded (heterogeneous-population cells, see make_user_masks)."""
    viol = (metrics["d_tl"] > cfg.tau).astype(jnp.float32)
    return -masked_mean(metrics["G"] + viol * cfg.chi, mask)


def env_step_slot(state: EnvState, cfg: EnvCfg, models: ModelParams, b, xi,
                  mask=None, mod: SlotMod | None = None):
    """Execute allocation (b, xi) on the current slot, then draw the next
    slot's randomness.

    Parameters
    ----------
    state : EnvState
        Current slot state (randomness for this slot already drawn).
    cfg : EnvCfg
        Static environment configuration.
    models : ModelParams
        The cell's GenAI model zoo.
    b, xi : jnp.ndarray
        Amended (U,) bandwidth and compute shares (simplex constraints
        (11e)-(11g) already enforced by ``amend_actions``).
    mask : jnp.ndarray, optional
        (U,) 0/1 active-user mask; inactive users are excluded from the
        reward average (heterogeneous-population cells).
    mod : SlotMod, optional
        Scenario modulation for the *next* slot's draws — slot g's metrics
        always consume randomness that was modulated when drawn (DESIGN.md
        §9).  ``None`` keeps the byte-identical paper-default stream.

    Returns
    -------
    (EnvState, jnp.ndarray, dict)
        Next-slot state, scalar reward (Eq. 23), and the per-user metric
        dict from ``slot_metrics``.
    """
    metrics = slot_metrics(state, cfg, models, b, xi)
    r = slot_reward(metrics, cfg, mask)
    k, knext = jax.random.split(state.key)
    nxt = _refresh_slot(k, state._replace(key=knext), cfg, mod=mod)
    return nxt, r, metrics


# -- observation (Eq. 21) -------------------------------------------------------

def observe(state: EnvState, cfg: EnvCfg, models: ModelParams, mask=None):
    """s_t(k) = {h, phi, rho, d_in, d_op} normalised to O(1) ranges.

    With ``mask``, inactive users' features are zeroed so cells with fewer
    than U users present a consistent observation to the shared actor."""
    h_n = (jnp.log10(state.h + 1e-30) + 12.0) / 5.0
    req_n = state.req.astype(jnp.float32) / cfg.M
    din_n = state.d_in / (cfg.d_in_mb[1] * MB_BITS)
    dop_n = models.d_op[state.req] / (cfg.d_op_mb[1] * MB_BITS)
    if mask is not None:
        h_n, req_n = h_n * mask, req_n * mask
        din_n, dop_n = din_n * mask, dop_n * mask
    return jnp.concatenate([h_n, req_n, state.rho, din_n, dop_n])
