"""Fixed-capacity cyclic replay buffers as pure pytrees (jit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def buffer_init(capacity: int, item_example):
    """item_example: pytree of arrays defining per-item shapes/dtypes."""
    data = jax.tree.map(
        lambda a: jnp.zeros((capacity,) + jnp.shape(a), jnp.asarray(a).dtype),
        item_example)
    return {"data": data, "ptr": jnp.int32(0), "size": jnp.int32(0)}


def _capacity(buf) -> int:
    return jax.tree.leaves(buf["data"])[0].shape[0]


def buffer_add(buf, item):
    ptr = buf["ptr"]
    data = jax.tree.map(lambda d, x: d.at[ptr].set(x), buf["data"], item)
    cap = _capacity(buf)
    return {"data": data, "ptr": (ptr + 1) % cap,
            "size": jnp.minimum(buf["size"] + 1, cap)}


def buffer_sample(buf, key, batch: int):
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf["size"], 1))
    return jax.tree.map(lambda d: d[idx], buf["data"])
