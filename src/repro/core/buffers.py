"""Fixed-capacity cyclic replay buffers as pure pytrees (jit-friendly)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def buffer_init(capacity: int, item_example):
    """item_example: pytree of arrays defining per-item shapes/dtypes."""
    data = jax.tree.map(
        lambda a: jnp.zeros((capacity,) + jnp.shape(a), jnp.asarray(a).dtype),
        item_example)
    return {"data": data, "ptr": jnp.int32(0), "size": jnp.int32(0)}


def _capacity(buf) -> int:
    return jax.tree.leaves(buf["data"])[0].shape[0]


def buffer_add(buf, item):
    ptr = buf["ptr"]
    data = jax.tree.map(lambda d, x: d.at[ptr].set(x), buf["data"], item)
    cap = _capacity(buf)
    return {"data": data, "ptr": (ptr + 1) % cap,
            "size": jnp.minimum(buf["size"] + 1, cap)}


def buffer_occupancy(buf, prefix: str, capacity: int | None = None) -> dict:
    """Telemetry (DESIGN.md §15): ``{prefix_size, prefix_fill}`` — stored
    items and fill fraction.  A per-env ``size`` of shape (B,) rides
    through unchanged; pass ``capacity`` explicitly for batched/stacked
    layouts whose leading leaf axis is B, not the capacity.  Sampling is
    uniform, so occupancy is the whole replay story — there are no
    priority weights to report."""
    cap = _capacity(buf) if capacity is None else capacity
    size = buf["size"]
    return {prefix + "_size": size.astype(jnp.float32),
            prefix + "_fill": size.astype(jnp.float32) / cap}


def buffer_sample(buf, key, batch: int):
    """Uniform minibatch draw **with replacement** (DESIGN.md §12).

    With-replacement is intentional: an exact without-replacement draw under
    jit needs a masked top-k over the full capacity (~2x the key-derived
    randint cost per update, measured on the 2-core CI box), while for the
    steady-state regime (size >> batch, e.g. 10000 vs 64) the collision
    probability per draw is < batch/size ≈ 0.6% — the occasional duplicate
    row only reweights a gradient contribution.  ``tests/test_agents.py``
    pins the sampling contract (in-range indices, stored items only,
    deterministic given the key)."""
    idx = jax.random.randint(key, (batch,), 0, jnp.maximum(buf["size"], 1))
    return jax.tree.map(lambda d: d[idx], buf["data"])


def buffer_add_many(buf, items):
    """Append ``n`` items in one batched write; items' leaves carry a
    leading ``(n,)`` axis (oldest first).  Equivalent to ``n`` successive
    ``buffer_add`` calls — same final data/ptr/size, including cyclic
    wraparound (``n`` may exceed the remaining headroom but not the
    capacity) — at the cost of ONE scatter per leaf instead of ``n``.
    The episode driver uses this to batch replay writes once per frame
    (DESIGN.md §12)."""
    n = jax.tree.leaves(items)[0].shape[0]
    cap = _capacity(buf)
    if n > cap:
        # duplicate scatter indices would make the surviving rows depend on
        # XLA's scatter order — refuse instead of silently losing determinism
        raise ValueError(f"buffer_add_many: cannot write {n} items into a "
                         f"buffer of capacity {cap}; writes batched per "
                         f"frame require capacity >= K")
    idx = (buf["ptr"] + jnp.arange(n)) % cap
    data = jax.tree.map(lambda d, x: d.at[idx].set(x), buf["data"], items)
    return {"data": data, "ptr": (buf["ptr"] + n) % cap,
            "size": jnp.minimum(buf["size"] + n, cap)}


# -- batched (per-env leading axis) -------------------------------------------
#
# The vectorized trainer keeps B independent replay buffers as one pytree
# with a leading (B,) axis on every leaf, including ptr/size.  Each cell
# writes and wraps around independently; the helpers below are the public
# contract (DESIGN.md §6) and are what run_episode becomes under vmap.

def buffer_init_batch(num_envs: int, capacity: int, item_example):
    """B independent buffers: leaves are (B, capacity, ...) with per-env
    ptr/size of shape (B,)."""
    buf = buffer_init(capacity, item_example)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (num_envs,) + a.shape).copy(), buf)


def buffer_add_batch(buf, items):
    """Add one item per env; items' leaves carry a leading (B,) axis."""
    return jax.vmap(buffer_add)(buf, items)


def buffer_add_many_batch(buf, items):
    """Per-env batched append: items' leaves are (B, n, ...) — ``n`` items
    for each of the B independent buffers, one scatter per env per leaf."""
    return jax.vmap(buffer_add_many)(buf, items)


def buffer_sample_batch(buf, keys, batch: int):
    """Sample a (B, batch, ...) minibatch — one independent draw per env.
    keys: (B, 2) PRNG keys."""
    return jax.vmap(buffer_sample, in_axes=(0, 0, None))(buf, keys, batch)


# -- fused (DESIGN.md §13) ----------------------------------------------------
#
# Same layout as the *_batch helpers, but the gathers/scatters of all B
# cells execute as ONE indexed op per leaf instead of B vmapped ops.  The
# per-cell randint draws stay vmapped so the index streams (and thus the
# sampled minibatches) are bit-identical to buffer_sample_batch — pinned
# by tests/test_fused.py.

def buffer_sample_stacked(buf, keys, batch: int):
    """Fused ``buffer_sample_batch``: one (B, batch) gather per leaf."""
    idx = jax.vmap(
        lambda k, s: jax.random.randint(k, (batch,), 0, jnp.maximum(s, 1))
    )(keys, buf["size"])                                        # (B, batch)
    b_ix = jnp.arange(idx.shape[0])[:, None]
    return jax.tree.map(lambda d: d[b_ix, idx], buf["data"])


def buffer_add_many_stacked(buf, items):
    """Fused ``buffer_add_many_batch``: items' leaves are (B, n, ...);
    all B cyclic writes land in one scatter per leaf."""
    n = jax.tree.leaves(items)[0].shape[1]
    cap = _capacity({"data": jax.tree.map(lambda d: d[0], buf["data"])})
    if n > cap:
        raise ValueError(f"buffer_add_many_stacked: cannot write {n} items "
                         f"into buffers of capacity {cap}")
    idx = (buf["ptr"][:, None] + jnp.arange(n)[None, :]) % cap  # (B, n)
    b_ix = jnp.arange(idx.shape[0])[:, None]
    data = jax.tree.map(lambda d, x: d.at[b_ix, idx].set(x),
                        buf["data"], items)
    return {"data": data, "ptr": (buf["ptr"] + n) % cap,
            "size": jnp.minimum(buf["size"] + n, cap)}
