"""Classical cache-hierarchy state machines (DESIGN.md §14): LRU / LFU /
ghost-augmented LRU / ARC as jit/scan-safe fixed-size array programs.

These are the adaptive baselines the DDQN cacher has to beat — a learned
cacher that cannot beat ARC rejects nothing (ROADMAP).  Unlike the usual
pointer-and-dict implementations, every policy here is a pure function over
a fixed-size state dict of ``(M,)`` membership/timestamp arrays, so it
scans, vmaps, and checkpoints exactly like the learned agents:

- the item universe is the ``M`` GenAI model types, so recency/frequency/
  ghost *lists* are encoded as ``(M,)`` membership masks plus ``(M,)``
  int32 access/ghost timestamps (list order = timestamp order, ties are
  impossible for live timestamps and break toward the lowest model id via
  argmin-first-occurrence);
- capacity is accounted in INTEGER size units (``SIZE_UNITS_PER_GB``-ths
  of a GB, conservatively rounded: item sizes ceil, capacity floor), so
  every admission/eviction decision is exact integer arithmetic — the
  pure-Python references in ``tests/_cache_refs.py`` reproduce the jitted
  decision traces bit-for-bit, which is what the differential test suite
  (``tests/test_cachers.py``) pins;
- eviction loops are ``fori_loop``s bounded by ``M`` (each pass evicts at
  most one resident item), never data-dependent ``while`` loops.

Scan-safe ARC (vs pointer ARC, Megiddo & Modha 2003): the four cases are
computed branch-free and gated by the case booleans; REPLACE ghosts every
cache eviction (T1→B1, T2→B2); and instead of the textbook pre-insert
directory juggling, the ARC directory invariants (|T1|+|B1| ≤ c in size
units, total directory ≤ 2c) are restored by trimming the OLDEST ghosts
after every access.  The adaptation target ``p`` lives in integer size
units and moves by ``max(size(x), (other_ghost_units // own_ghost_units) *
size(x))`` — the size-aware analogue of the classic ±max(1, |B2|/|B1|).

Every ``*_access`` has the same signature::

    state, info = <kind>_access(state, m, c_units, cap_units, valid)

``m`` the accessed model id, ``c_units`` the ``(M,)`` int32 item sizes,
``cap_units`` the capacity, ``valid`` a bool gate (False = full no-op, the
lever that makes masked-user streams scan-safe).  ``info`` records the
decision trace: ``hit``, ``admitted``, and the ``(M,)`` ``evicted`` mask.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# Integer capacity resolution: 64 units per GB (power of two, so the
# float32 GB -> unit scaling in quantize_sizes is exact).
SIZE_UNITS_PER_GB = 64

_I32_MAX = jnp.int32(2 ** 31 - 1)

CACHE_POLICIES = ("lru", "lfu", "lru-ghost", "arc")


def quantize_sizes(c) -> jnp.ndarray:
    """Model sizes (GB, float) -> conservative integer units (ceil)."""
    return jnp.ceil(jnp.asarray(c) * SIZE_UNITS_PER_GB).astype(jnp.int32)


def quantize_capacity(C: float) -> int:
    """Cache capacity (GB) -> conservative integer units (floor).

    ceil on items + floor on capacity means a unit-feasible cache content
    is always GB-feasible: ``sum(c * rho) <= sum(c_units) / Q <=
    cap_units / Q <= C`` — classical cachers can never trip the storage
    penalty (11d)."""
    return int(math.floor(C * SIZE_UNITS_PER_GB))


def cache_state_init(M: int) -> dict:
    """Fresh (empty) cache state — one fixed layout for every policy.

    ``in_t1``/``in_t2`` are the resident lists (plain LRU/LFU use only
    ``in_t1``; ARC splits recent/frequent), ``in_b1``/``in_b2`` the ghost
    lists, ``last``/``glast`` the access/ghost-entry clocks, ``freq`` the
    in-cache access counts (LFU), ``time`` the logical access clock and
    ``p`` ARC's adaptation target in size units.  Unused leaves stay at
    their init value, so the TrainState ``"cache"`` slot has one shape
    regardless of which cacher runs (DESIGN.md §12/§14)."""
    z = jnp.zeros((M,), jnp.bool_)
    return {
        "in_t1": z, "in_t2": z, "in_b1": z, "in_b2": z,
        "last": jnp.full((M,), -1, jnp.int32),
        "glast": jnp.full((M,), -1, jnp.int32),
        "freq": jnp.zeros((M,), jnp.int32),
        "time": jnp.int32(0),
        "p": jnp.int32(0),
    }


def cache_rho(state) -> jnp.ndarray:
    """Resident set as the env's float 0/1 caching vector (batch-safe)."""
    return (state["in_t1"] | state["in_t2"]).astype(jnp.float32)


def _units(members, c_units):
    """Total size units of a membership mask (exact integer sum)."""
    return jnp.sum(jnp.where(members, c_units, 0))


def _evict_oldest(members, order, c_units, budget):
    """Evict members in increasing ``order`` (argmin-first: ties -> lowest
    id) until their total size fits ``budget``.  Returns the trimmed mask
    and the evicted mask.  Bounded ``fori_loop`` over M."""
    M = members.shape[0]

    def body(_, carry):
        mem, ev = carry
        need = _units(mem, c_units) > budget
        victim = jnp.argmin(jnp.where(mem, order, _I32_MAX))
        do = need & jnp.any(mem)
        return (jnp.where(do, mem.at[victim].set(False), mem),
                jnp.where(do, ev.at[victim].set(True), ev))

    return jax.lax.fori_loop(
        0, M, body, (members, jnp.zeros((M,), jnp.bool_)))


def _gate(valid, new_state, old_state, info):
    """valid=False -> full no-op (state unchanged, all-false trace)."""
    state = jax.tree.map(lambda n, o: jnp.where(valid, n, o),
                         new_state, old_state)
    info = {k: jnp.where(valid, v, jnp.zeros_like(v))
            for k, v in info.items()}
    return state, info


# -- LRU ----------------------------------------------------------------------

def lru_access(state, m, c_units, cap_units, valid=True):
    """Least-recently-used: hit refreshes recency; a miss that can ever fit
    (size <= capacity) evicts LRU residents until it fits, then admits."""
    t = state["time"] + 1
    in_c, last = state["in_t1"], state["last"]
    hit = in_c[m]
    fits = c_units[m] <= cap_units
    admit = ~hit & fits
    mem_m, ev = _evict_oldest(in_c, last, c_units,
                              cap_units - c_units[m])
    in_c_new = jnp.where(hit, in_c,
                         jnp.where(admit, mem_m.at[m].set(True), in_c))
    last_new = jnp.where(hit | admit, last.at[m].set(t), last)
    new = dict(state, in_t1=in_c_new, last=last_new, time=t)
    info = {"hit": hit, "admitted": admit,
            "evicted": jnp.where(admit, ev, jnp.zeros_like(ev))}
    return _gate(valid, new, state, info)


# -- LFU ----------------------------------------------------------------------

def _evict_lfu(members, freq, last, c_units, budget):
    """LFU eviction: lowest in-cache frequency first, ties by least-recent
    access, then lowest id.  Evicted items have their count reset (no
    frequency memory across residencies)."""
    M = members.shape[0]

    def body(_, carry):
        mem, fr, ev = carry
        need = _units(mem, c_units) > budget
        fmin = jnp.min(jnp.where(mem, fr, _I32_MAX))
        cand = mem & (fr == fmin)
        victim = jnp.argmin(jnp.where(cand, last, _I32_MAX))
        do = need & jnp.any(mem)
        return (jnp.where(do, mem.at[victim].set(False), mem),
                jnp.where(do, fr.at[victim].set(0), fr),
                jnp.where(do, ev.at[victim].set(True), ev))

    return jax.lax.fori_loop(
        0, M, body, (members, freq, jnp.zeros((M,), jnp.bool_)))


def lfu_access(state, m, c_units, cap_units, valid=True):
    """Least-frequently-used with in-cache counts (reset on eviction);
    recency breaks frequency ties."""
    t = state["time"] + 1
    in_c, last, freq = state["in_t1"], state["last"], state["freq"]
    hit = in_c[m]
    fits = c_units[m] <= cap_units
    admit = ~hit & fits
    mem_m, freq_m, ev = _evict_lfu(in_c, freq, last, c_units,
                                   cap_units - c_units[m])
    in_c_new = jnp.where(hit, in_c,
                         jnp.where(admit, mem_m.at[m].set(True), in_c))
    freq_new = jnp.where(hit, freq.at[m].add(1),
                         jnp.where(admit, freq_m.at[m].set(1), freq))
    last_new = jnp.where(hit | admit, last.at[m].set(t), last)
    new = dict(state, in_t1=in_c_new, last=last_new, freq=freq_new, time=t)
    info = {"hit": hit, "admitted": admit,
            "evicted": jnp.where(admit, ev, jnp.zeros_like(ev))}
    return _gate(valid, new, state, info)


# -- ghost-augmented LRU (admission-filtered) ---------------------------------

def lru_ghost_access(state, m, c_units, cap_units, valid=True):
    """LRU with a ghost-list admission filter (a TinyLFU-style doorkeeper):
    a first-touch miss only RECORDS the id in the ghost list; a miss whose
    id is ghost-listed (recently seen or recently evicted) is admitted.
    One-hit wonders therefore never displace residents.  Evicted items
    re-enter the ghost list; the ghost list itself is LRU-bounded to
    ``cap_units`` worth of ids."""
    t = state["time"] + 1
    in_c, in_g = state["in_t1"], state["in_b1"]
    last, glast = state["last"], state["glast"]
    hit = in_c[m]
    fits = c_units[m] <= cap_units
    ghost_hit = ~hit & in_g[m]
    admit = ghost_hit & fits
    record = ~hit & ~ghost_hit            # first touch: doorkeeper entry
    mem_m, ev = _evict_oldest(in_c, last, c_units,
                              cap_units - c_units[m])
    ev = jnp.where(admit, ev, jnp.zeros_like(ev))
    in_c_new = jnp.where(hit, in_c,
                         jnp.where(admit, mem_m.at[m].set(True), in_c))
    last_new = jnp.where(hit | admit, last.at[m].set(t), last)
    # ghost bookkeeping: admitted ids leave, victims and first-touches enter
    in_g_new = jnp.where(admit, in_g.at[m].set(False), in_g)
    in_g_new = in_g_new | ev
    in_g_new = jnp.where(record, in_g_new.at[m].set(True), in_g_new)
    glast_new = jnp.where(ev, t, glast)
    glast_new = jnp.where(record, glast_new.at[m].set(t), glast_new)
    in_g_new, _ = _evict_oldest(in_g_new, glast_new, c_units, cap_units)
    new = dict(state, in_t1=in_c_new, in_b1=in_g_new, last=last_new,
               glast=glast_new, time=t)
    info = {"hit": hit, "admitted": admit, "evicted": ev}
    return _gate(valid, new, state, info)


# -- ARC ----------------------------------------------------------------------

def _arc_replace(t1, t2, b1, b2, last, glast, p, b2_hit, do, size_m,
                 c_units, cap_units, t):
    """ARC REPLACE, size-aware: evict LRU of T1 (to B1) while T1 exceeds
    the target ``p`` — or of T2 (to B2) otherwise — until ``size_m`` more
    units fit.  ``do`` gates the whole loop (hits / oversize bypasses)."""
    M = t1.shape[0]

    def body(_, carry):
        t1, t2, b1, b2, glast, ev = carry
        t1u, t2u = _units(t1, c_units), _units(t2, c_units)
        need = do & (t1u + t2u + size_m > cap_units)
        any1, any2 = jnp.any(t1), jnp.any(t2)
        pick1 = any1 & ((t1u > p) | (b2_hit & (t1u == p)) | ~any2)
        v1 = jnp.argmin(jnp.where(t1, last, _I32_MAX))
        v2 = jnp.argmin(jnp.where(t2, last, _I32_MAX))
        do1 = need & (any1 | any2) & pick1
        do2 = need & (any1 | any2) & ~pick1
        t1 = jnp.where(do1, t1.at[v1].set(False), t1)
        b1 = jnp.where(do1, b1.at[v1].set(True), b1)
        glast = jnp.where(do1, glast.at[v1].set(t), glast)
        ev = jnp.where(do1, ev.at[v1].set(True), ev)
        t2 = jnp.where(do2, t2.at[v2].set(False), t2)
        b2 = jnp.where(do2, b2.at[v2].set(True), b2)
        glast = jnp.where(do2, glast.at[v2].set(t), glast)
        ev = jnp.where(do2, ev.at[v2].set(True), ev)
        return t1, t2, b1, b2, glast, ev

    return jax.lax.fori_loop(
        0, M, body, (t1, t2, b1, b2, glast, jnp.zeros((M,), jnp.bool_)))


def arc_access(state, m, c_units, cap_units, valid=True):
    """Adaptive Replacement Cache, scan-safe and size-aware (module
    docstring; DESIGN.md §14).  Cases: resident hit promotes to T2; B1/B2
    ghost hits steer ``p`` toward recency/frequency and re-admit into T2;
    cold misses admit into T1.  Ghost-directory invariants (T1+B1 <= cap,
    directory total <= 2*cap in size units) are restored by trimming the
    oldest ghosts after the access."""
    cap_units = jnp.int32(cap_units)
    t = state["time"] + 1
    t1, t2 = state["in_t1"], state["in_t2"]
    b1, b2 = state["in_b1"], state["in_b2"]
    last, glast, p = state["last"], state["glast"], state["p"]
    size_m = c_units[m]
    fits = size_m <= cap_units
    hit = t1[m] | t2[m]
    b1_hit = ~hit & b1[m]
    b2_hit = ~hit & b2[m]
    admit = ~hit & fits                       # ghost hits and cold misses
    b1u, b2u = _units(b1, c_units), _units(b2, c_units)
    # adaptation: B1 hit grows the recency target, B2 hit shrinks it
    one = jnp.int32(1)
    d1 = jnp.maximum(size_m, (b2u // jnp.maximum(b1u, one)) * size_m)
    d2 = jnp.maximum(size_m, (b1u // jnp.maximum(b2u, one)) * size_m)
    p_new = jnp.where(b1_hit, jnp.minimum(p + d1, jnp.int32(cap_units)),
                      jnp.where(b2_hit, jnp.maximum(p - d2, 0), p))
    t1, t2, b1, b2, glast, ev = _arc_replace(
        t1, t2, b1, b2, last, glast, p_new, b2_hit, admit, size_m,
        c_units, cap_units, t)
    # resident hit: T1 -> T2 promotion (T2 hit: recency refresh only)
    t1 = jnp.where(hit, t1.at[m].set(False), t1)
    t2 = jnp.where(hit, t2.at[m].set(True), t2)
    # admission: ghost hits re-enter as frequent (T2), cold misses as
    # recent (T1); the id leaves the ghost directory
    ghost_admit = admit & (b1_hit | b2_hit)
    cold_admit = admit & ~(b1_hit | b2_hit)
    b1 = jnp.where(ghost_admit, b1.at[m].set(False), b1)
    b2 = jnp.where(ghost_admit, b2.at[m].set(False), b2)
    t2 = jnp.where(ghost_admit, t2.at[m].set(True), t2)
    t1 = jnp.where(cold_admit, t1.at[m].set(True), t1)
    last = jnp.where(hit | admit, last.at[m].set(t), last)
    # directory trims (oldest ghosts first): T1+B1 <= cap, total <= 2*cap
    t1u = _units(t1, c_units)
    b1, _ = _evict_oldest(b1, glast, c_units,
                          jnp.maximum(jnp.int32(cap_units) - t1u, 0))
    tot = t1u + _units(t2, c_units) + _units(b1, c_units)
    b2, _ = _evict_oldest(b2, glast, c_units,
                          jnp.maximum(jnp.int32(2 * cap_units) - tot, 0))
    new = dict(state, in_t1=t1, in_t2=t2, in_b1=b1, in_b2=b2, last=last,
               glast=glast, p=p_new, time=t)
    info = {"hit": hit, "admitted": admit, "evicted": ev}
    return _gate(valid, new, state, info)


_ACCESS = {"lru": lru_access, "lfu": lfu_access,
           "lru-ghost": lru_ghost_access, "arc": arc_access}


def cache_access(kind: str, state, m, c_units, cap_units, valid=True):
    """Dispatch one access through policy ``kind`` (jit-static string) —
    the single place classical policy kinds are branched on."""
    if kind not in _ACCESS:
        raise ValueError(f"unknown cache policy {kind!r}; expected one of "
                         f"{CACHE_POLICIES}")
    return _ACCESS[kind](state, m, c_units, cap_units, valid)
