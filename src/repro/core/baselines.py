"""Benchmark solutions (paper Sec. 7.2).

SCHRS — static caching (most popular models under gamma_1 = 0.2, greedy fill
to capacity) + per-slot genetic algorithm over allocation chromosomes with
simulated-binary crossover (SBX) and polynomial mutation.

RCARS — random caching to capacity + equal bandwidth / compute split.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .d3pg import amend_actions
from .env import EnvCfg, EnvState, ModelParams, slot_metrics, slot_reward


# -- caching policies ---------------------------------------------------------

def static_popular_cache(models: ModelParams, cfg: EnvCfg) -> jnp.ndarray:
    """Cache the most popular models (Zipf rank = model id) greedily until
    the capacity C is exhausted (skipping models that do not fit)."""
    def body(carry, cm):
        used, = carry
        take = (used + cm) <= cfg.C
        return (used + jnp.where(take, cm, 0.0),), take.astype(jnp.float32)
    (_,), rho = jax.lax.scan(body, (jnp.float32(0.0),), models.c)
    return rho


def random_cache(key, models: ModelParams, cfg: EnvCfg) -> jnp.ndarray:
    """Random order greedy fill (RCARS)."""
    perm = jax.random.permutation(key, cfg.M)
    def body(carry, m):
        used, rho = carry
        take = (used + models.c[m]) <= cfg.C
        rho = rho.at[m].set(take.astype(jnp.float32))
        return (used + jnp.where(take, models.c[m], 0.0), rho), None
    (_, rho), _ = jax.lax.scan(body, (jnp.float32(0.0),
                                      jnp.zeros(cfg.M)), perm)
    return rho


def static_popular_cache_batch(models: ModelParams, cfg: EnvCfg):
    """Per-cell SCHRS caching for a batched model zoo (leading (B,) axis)."""
    return jax.vmap(lambda m: static_popular_cache(m, cfg))(models)


def random_cache_batch(keys, models: ModelParams, cfg: EnvCfg):
    """Per-cell RCARS caching; keys: (B, 2), models batched on axis 0."""
    return jax.vmap(lambda k, m: random_cache(k, m, cfg))(keys, models)


# -- RCARS allocation ---------------------------------------------------------

def rcars_allocate(state: EnvState, cfg: EnvCfg):
    b = jnp.full((cfg.U,), 1.0 / cfg.U)
    gate = state.rho[state.req]
    xi = gate / (jnp.sum(gate) + 1e-9)
    return b, xi


# -- SCHRS genetic algorithm ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GACfg:
    pop: int = 40
    gens: int = 40
    eta_c: float = 15.0     # SBX distribution index
    eta_m: float = 20.0     # polynomial-mutation distribution index
    pm: float = 0.08        # per-gene mutation probability
    pc: float = 0.9         # crossover probability


def _sbx(key, p1, p2, eta):
    u = jax.random.uniform(key, p1.shape)
    beta = jnp.where(u <= 0.5,
                     (2.0 * u) ** (1.0 / (eta + 1.0)),
                     (1.0 / (2.0 * (1.0 - u) + 1e-12)) ** (1.0 / (eta + 1.0)))
    c1 = 0.5 * ((1 + beta) * p1 + (1 - beta) * p2)
    c2 = 0.5 * ((1 - beta) * p1 + (1 + beta) * p2)
    return jnp.clip(c1, 0.0, 1.0), jnp.clip(c2, 0.0, 1.0)


def _poly_mutation(key, x, eta, pm):
    k1, k2 = jax.random.split(key)
    u = jax.random.uniform(k1, x.shape)
    delta = jnp.where(u < 0.5,
                      (2.0 * u) ** (1.0 / (eta + 1.0)) - 1.0,
                      1.0 - (2.0 * (1.0 - u)) ** (1.0 / (eta + 1.0)))
    mutate = jax.random.uniform(k2, x.shape) < pm
    return jnp.clip(x + jnp.where(mutate, delta, 0.0), 0.0, 1.0)


def ga_allocate(key, state: EnvState, cfg: EnvCfg, models: ModelParams,
                ga: GACfg = GACfg()):
    """Evolve allocation chromosomes for the current slot; returns (b, xi).

    Fitness = the slot objective (12) plus the deadline penalty of (23), so
    the GA respects constraint (11h) the same way the DRL agents do.  The
    population is warm-started with the all-0.5 chromosome (which amends
    to the equal split over active/cached users); with elitism this
    guarantees the result is never worse (in fitness) than that amended
    warm-start point."""
    U = cfg.U

    def fitness(chrom):
        b, xi = amend_actions(chrom, state.req, state.rho, U)
        m = slot_metrics(state, cfg, models, b, xi)
        viol = (m["d_tl"] > cfg.tau).astype(jnp.float32)
        return jnp.mean(m["G"] + viol * cfg.chi)

    k0, key = jax.random.split(key)
    pop = jax.random.uniform(k0, (ga.pop, 2 * U))
    pop = pop.at[0].set(0.5)    # warm start: amends to the equal split
    fit = jax.vmap(fitness)(pop)

    def gen(carry, k):
        pop, fit = carry
        k1, k2, k3, k4 = jax.random.split(k, 4)
        # binary tournament selection
        idx = jax.random.randint(k1, (2, ga.pop), 0, ga.pop)
        winners = jnp.where((fit[idx[0]] < fit[idx[1]])[:, None],
                            pop[idx[0]], pop[idx[1]])
        # SBX on consecutive pairs
        p1, p2 = winners[0::2], winners[1::2]
        c1, c2 = _sbx(k2, p1, p2, ga.eta_c)
        do_cx = (jax.random.uniform(k3, (ga.pop // 2, 1)) < ga.pc)
        c1 = jnp.where(do_cx, c1, p1)
        c2 = jnp.where(do_cx, c2, p2)
        children = jnp.concatenate([c1, c2], axis=0)
        children = _poly_mutation(k4, children, ga.eta_m, ga.pm)
        child_fit = jax.vmap(fitness)(children)
        # elitism: keep the best individual seen so far
        best = jnp.argmin(fit)
        children = children.at[0].set(pop[best])
        child_fit = child_fit.at[0].set(fit[best])
        return (children, child_fit), None

    (pop, fit), _ = jax.lax.scan(gen, (pop, fit),
                                 jax.random.split(key, ga.gens))
    best = pop[jnp.argmin(fit)]
    return amend_actions(best, state.req, state.rho, U)
