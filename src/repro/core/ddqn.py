"""DDQN for the long-timescale model-caching subproblem P3 (paper Sec. 6.3).

State: the popularity skewness state gamma(t) (one-hot over J).  Action: an
integer in [0, 2^M) decoded to the caching vector rho by the paper's
floor/mod amender; storage feasibility (11d) is encouraged via the penalty Xi
in the frame reward (32).  A beyond-paper greedy-feasible amender (drop the
largest cached model until (11d) holds) is available behind a flag.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.optim import adam_init, adam_update, adam_update_stacked
from .networks import (mlp_apply, mlp_apply_stacked, mlp_init, soft_update)


@dataclasses.dataclass(frozen=True)
class DDQNCfg:
    M: int = 10                  # GenAI model types -> 2^M actions
    J: int = 3                   # popularity states
    hidden: int = 128            # paper: 2 FC layers of 128
    n_hidden: int = 2
    lr: float = 1e-6             # paper's Adam lr
    rho: float = 0.9             # discount (frame-level)
    kappa: float = 0.005         # target update rate (35)
    batch: int = 32
    buffer: int = 2048
    feasible_amender: bool = False   # beyond-paper (off by default)

    @property
    def n_actions(self) -> int:
        return 2 ** self.M


def ddqn_init(key, cfg: DDQNCfg):
    dims = [cfg.J] + [cfg.hidden] * cfg.n_hidden + [cfg.n_actions]
    q = mlp_init(key, dims)
    return {"q": q, "q_target": jax.tree.map(jnp.copy, q),
            "opt": adam_init(q)}


def _obs(gamma_idx, cfg: DDQNCfg):
    return jax.nn.one_hot(gamma_idx, cfg.J)


def ddqn_act(params, cfg: DDQNCfg, gamma_idx, key, eps):
    """epsilon-greedy over the 2^M caching actions.  ``gamma_idx`` may be a
    scalar or carry leading batch axes (one key drives the whole batch)."""
    qv = mlp_apply(params["q"], _obs(gamma_idx, cfg))
    greedy = jnp.argmax(qv, axis=-1)
    k1, k2 = jax.random.split(key)
    rand = jax.random.randint(k1, greedy.shape, 0, cfg.n_actions)
    explore = jax.random.uniform(k2, greedy.shape) < eps
    return jnp.where(explore, rand, greedy).astype(jnp.int32)


def amend_caching(a_int, cfg: DDQNCfg, c=None, C: float = 0.0):
    """Paper's amender: rho_m = floor(a / 2^(M-m)) mod 2, batch-safe over
    leading axes of ``a_int``.  With ``cfg.feasible_amender`` also greedily
    evicts the largest cached model until the storage constraint (11d)
    holds (single-env only)."""
    m = jnp.arange(1, cfg.M + 1)
    rho = (jnp.asarray(a_int)[..., None] // (2 ** (cfg.M - m))) % 2
    rho = rho.astype(jnp.float32)
    if cfg.feasible_amender and c is not None:
        def evict(_, rho):
            over = jnp.sum(rho * c) > C
            largest = jnp.argmax(rho * c)
            return jnp.where(over, rho.at[largest].set(0.0), rho)
        rho = jax.lax.fori_loop(0, cfg.M, evict, rho)
    return rho


def _tree_l2(t):
    """Global l2 norm over a parameter/grad pytree."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(t)))


def _tree_l2_stacked(t):
    """Per-learner l2 norms, (B,), over a stacked pytree (leading B)."""
    total = sum(jnp.sum(jnp.square(l).reshape(l.shape[0], -1), axis=1)
                for l in jax.tree.leaves(t))
    return jnp.sqrt(total)


def _tree_diff_l2(a, b):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x - y)) for x, y in
                        zip(jax.tree.leaves(a), jax.tree.leaves(b))))


def _tree_diff_l2_stacked(a, b):
    total = sum(jnp.sum(jnp.square(x - y).reshape(x.shape[0], -1), axis=1)
                for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    return jnp.sqrt(total)


def ddqn_diag_zero(cfg: DDQNCfg) -> dict:
    """Zeros pytree matching the diag metrics of ``ddqn_update(diag=True)``
    (the skipped-update branch of the in-scan ``lax.cond`` tap)."""
    z = jnp.zeros((), jnp.float32)
    return {"loss": z, "td_abs_mean": z, "td_abs_max": z, "q_mean": z,
            "q_max": z, "target_div": z, "grad_norm": z}


def ddqn_update(params, cfg: DDQNCfg, batch, *, lr=None, diag=False):
    """One minibatch step of Eq. (33); batch: {s, a, r, s1} with s/s1 the
    gamma indices.  Returns (params, loss).

    ``diag=True`` (telemetry, DESIGN.md §15) instead returns
    ``(params, metrics)`` with per-update diagnostics — TD-error stats,
    Q-value mean/max, online/target divergence, gradient norm.  The
    ``diag=False`` path is deliberately left byte-identical to the
    pre-telemetry build."""
    if diag:
        return _ddqn_update_diag(params, cfg, batch, lr=lr)
    lr = cfg.lr if lr is None else lr
    s = _obs(batch["s"], cfg)
    s1 = _obs(batch["s1"], cfg)

    def loss_fn(q):
        qv = mlp_apply(q, s)                          # (B, 2^M)
        y = jnp.take_along_axis(qv, batch["a"][:, None], axis=1)[:, 0]
        # action selection by the online net, evaluation by the target (33a)
        a1 = jnp.argmax(mlp_apply(q, s1), axis=1)
        q1 = mlp_apply(params["q_target"], s1)
        y_hat = batch["r"] + cfg.rho * jnp.take_along_axis(
            q1, a1[:, None], axis=1)[:, 0]
        return jnp.mean(0.5 * (jax.lax.stop_gradient(y_hat) - y) ** 2)

    loss, grads = jax.value_and_grad(loss_fn)(params["q"])
    q_new, opt_new, _ = adam_update(grads, params["opt"], params["q"], lr=lr)
    return {"q": q_new,
            "q_target": soft_update(params["q_target"], q_new, cfg.kappa),
            "opt": opt_new}, loss


def _ddqn_update_diag(params, cfg: DDQNCfg, batch, *, lr=None):
    """``ddqn_update`` with the telemetry tap: same math, same update,
    plus a diagnostics dict (keys pinned by ``ddqn_diag_zero``)."""
    lr = cfg.lr if lr is None else lr
    s = _obs(batch["s"], cfg)
    s1 = _obs(batch["s1"], cfg)

    def loss_fn(q):
        qv = mlp_apply(q, s)                          # (B, 2^M)
        y = jnp.take_along_axis(qv, batch["a"][:, None], axis=1)[:, 0]
        a1 = jnp.argmax(mlp_apply(q, s1), axis=1)
        q1 = mlp_apply(params["q_target"], s1)
        y_hat = batch["r"] + cfg.rho * jnp.take_along_axis(
            q1, a1[:, None], axis=1)[:, 0]
        td = jax.lax.stop_gradient(y_hat) - y
        return jnp.mean(0.5 * td ** 2), (td, qv)

    (loss, (td, qv)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params["q"])
    q_new, opt_new, _ = adam_update(grads, params["opt"], params["q"], lr=lr)
    q_target_new = soft_update(params["q_target"], q_new, cfg.kappa)
    metrics = {"loss": loss,
               "td_abs_mean": jnp.mean(jnp.abs(td)),
               "td_abs_max": jnp.max(jnp.abs(td)),
               "q_mean": jnp.mean(qv),
               "q_max": jnp.max(qv),
               "target_div": _tree_diff_l2(q_new, q_target_new),
               "grad_norm": _tree_l2(grads)}
    return {"q": q_new, "q_target": q_target_new, "opt": opt_new}, metrics

# Batched (per-env leading axis) init/update live behind the agent protocol:
# repro.agents.vmap_agent generically lifts any Agent to B stacked learners
# (ddqn_init_batch / ddqn_update_batch remain as shims in repro.agents).


# -- fused B-learner path (DESIGN.md §13) -------------------------------------


def ddqn_act_stacked(params, cfg: DDQNCfg, gamma_idx, keys, eps):
    """Fused epsilon-greedy for B stacked learners.  gamma_idx: (B,) —
    each learner's own popularity state; keys: (B, 2); eps: python
    scalar or per-learner (B,) array.  The per-learner key splits and
    randint/uniform draws stay vmapped, so the action stream is
    bit-identical to ``jax.vmap(ddqn_act)`` (tests/test_fused.py)."""
    qv = mlp_apply_stacked(params["q"], _obs(gamma_idx, cfg))
    greedy = jnp.argmax(qv, axis=-1)                         # (B,)
    kk = jax.vmap(jax.random.split)(keys)                    # (B, 2, 2)
    rand = jax.vmap(
        lambda k: jax.random.randint(k, (), 0, cfg.n_actions))(kk[:, 0])
    explore = jax.vmap(lambda k: jax.random.uniform(k, ()))(kk[:, 1]) < eps
    return jnp.where(explore, rand, greedy).astype(jnp.int32)


def ddqn_update_stacked(params, cfg: DDQNCfg, batch, *, lr=None, diag=False):
    """Fused ``ddqn_update`` over B stacked learners.  batch leaves carry
    a leading ``(B,)`` axis (each learner's own minibatch); ``lr`` is a
    python scalar or per-learner ``(B,)`` array.  Returns
    ``(params, loss)`` with per-learner losses ``(B,)`` exactly like
    ``jax.vmap(ddqn_update)``.  ``diag=True`` returns ``(params,
    metrics)`` with per-learner ``(B,)`` diagnostics instead (same key
    set as ``ddqn_diag_zero``)."""
    if diag:
        return _ddqn_update_stacked_diag(params, cfg, batch, lr=lr)
    lr = cfg.lr if lr is None else lr
    s = _obs(batch["s"], cfg)
    s1 = _obs(batch["s1"], cfg)

    def loss_fn(q):
        qv = mlp_apply_stacked(q, s)                  # (B, n, 2^M)
        y = jnp.take_along_axis(qv, batch["a"][..., None], axis=-1)[..., 0]
        # action selection by the online net, evaluation by the target (33a)
        a1 = jnp.argmax(mlp_apply_stacked(q, s1), axis=-1)
        q1 = mlp_apply_stacked(params["q_target"], s1)
        y_hat = batch["r"] + cfg.rho * jnp.take_along_axis(
            q1, a1[..., None], axis=-1)[..., 0]
        per = jnp.mean(0.5 * (jax.lax.stop_gradient(y_hat) - y) ** 2,
                       axis=-1)                       # (B,)
        return jnp.sum(per), per

    (_, loss), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params["q"])
    q_new, opt_new, _ = adam_update_stacked(grads, params["opt"],
                                            params["q"], lr=lr)
    return {"q": q_new,
            "q_target": soft_update(params["q_target"], q_new, cfg.kappa),
            "opt": opt_new}, loss


def _ddqn_update_stacked_diag(params, cfg: DDQNCfg, batch, *, lr=None):
    """``ddqn_update_stacked`` with the telemetry tap: per-learner (B,)
    diagnostics alongside the same fused update."""
    lr = cfg.lr if lr is None else lr
    s = _obs(batch["s"], cfg)
    s1 = _obs(batch["s1"], cfg)

    def loss_fn(q):
        qv = mlp_apply_stacked(q, s)                  # (B, n, 2^M)
        y = jnp.take_along_axis(qv, batch["a"][..., None], axis=-1)[..., 0]
        a1 = jnp.argmax(mlp_apply_stacked(q, s1), axis=-1)
        q1 = mlp_apply_stacked(params["q_target"], s1)
        y_hat = batch["r"] + cfg.rho * jnp.take_along_axis(
            q1, a1[..., None], axis=-1)[..., 0]
        td = jax.lax.stop_gradient(y_hat) - y         # (B, n)
        per = jnp.mean(0.5 * td ** 2, axis=-1)        # (B,)
        return jnp.sum(per), (per, td, qv)

    (_, (loss, td, qv)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params["q"])
    q_new, opt_new, _ = adam_update_stacked(grads, params["opt"],
                                            params["q"], lr=lr)
    q_target_new = soft_update(params["q_target"], q_new, cfg.kappa)
    metrics = {"loss": loss,
               "td_abs_mean": jnp.mean(jnp.abs(td), axis=-1),
               "td_abs_max": jnp.max(jnp.abs(td), axis=-1),
               "q_mean": jnp.mean(qv, axis=(1, 2)),
               "q_max": jnp.max(qv, axis=(1, 2)),
               "target_div": _tree_diff_l2_stacked(q_new, q_target_new),
               "grad_norm": _tree_l2_stacked(grads)}
    return {"q": q_new, "q_target": q_target_new, "opt": opt_new}, metrics
