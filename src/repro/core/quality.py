"""Fitted AIGC service models (paper Sec. 3.4, Fig. 3).

Eq. (7): piecewise-linear TV quality vs. denoising steps — parameters
A1 (steps where quality starts improving), A2 (worst TV), A3 (steps where
quality saturates), A4 (best TV; lower TV = better image).

Eq. (8): linear generation delay vs. denoising steps — D = B1·steps + B2.

The paper fits A1=60, A2=110, A3=170, A4=28, B1=0.18, B2=5.74 for RePaint on
an RTX A5000; the simulation draws per-model parameters from the ranges in
Sec. 7.1 to emulate heterogeneous GenAI models.

Beyond the paper: for non-diffusion model families served by the edge
gateway the same curve shapes apply with the *decode token/step budget* as
the compute knob (autoregressive quality saturates with budget; latency is
affine in generated tokens) — see ``repro.serving.gateway``.
"""
from __future__ import annotations

import jax.numpy as jnp

# Paper's fitted constants (RePaint / CelebA-HQ, Fig. 3)
A1, A2, A3, A4 = 60.0, 110.0, 170.0, 28.0
B1, B2 = 0.18, 5.74


def tv_quality(steps, a1=A1, a2=A2, a3=A3, a4=A4):
    """Eq. (7): TV value of the generated image after ``steps`` denoising
    steps (lower is better).  Broadcasts over per-model parameter arrays."""
    slope = (a4 - a2) / (a3 - a1)
    mid = a2 + slope * (steps - a1)
    return jnp.where(steps <= a1, a2, jnp.where(steps >= a3, a4, mid))


def gen_delay(steps, b1=B1, b2=B2):
    """Eq. (8): image generation time for ``steps`` denoising steps."""
    return b1 * steps + b2


def cloud_quality(a4=A4):
    """Un-cached requests go to the cloud: best quality (Sec. 3.4.1)."""
    return a4


def cloud_delay(a3=A3, b1=B1, b2=B2):
    """Cloud allocates the minimum steps reaching best quality (Sec. 3.4.2)."""
    return b1 * a3 + b2
