"""The paper's contribution: two-timescale model caching + resource
allocation for edge AIGC services (environment, D3PG, DDQN, baselines,
T2DRL driver) — with a vectorized, fully-jitted multi-cell training core
(DESIGN.md §6)."""
from .env import (EnvCfg, EnvState, ModelParams, ScenarioSchedule,  # noqa: F401
                  SlotMod, env_reset, env_new_frame, env_reset_batch,
                  env_step_slot, make_models, make_models_batch,
                  make_user_masks, masked_mean, observe, schedule_frame_P,
                  schedule_slot_mod, slot_metrics, slot_reward)
from .quality import tv_quality, gen_delay  # noqa: F401
from .ddqn import (DDQNCfg, amend_caching, ddqn_act, ddqn_init,  # noqa: F401
                   ddqn_update)
from .d3pg import (D3PGCfg, actor_act, amend_actions, critic_q, d3pg_init,  # noqa: F401
                   d3pg_update, make_actor_schedule)
from .buffers import (buffer_add, buffer_add_batch, buffer_add_many,  # noqa: F401
                      buffer_add_many_batch, buffer_init, buffer_init_batch,
                      buffer_sample, buffer_sample_batch)
from .baselines import (GACfg, ga_allocate, random_cache,  # noqa: F401
                        random_cache_batch, rcars_allocate,
                        static_popular_cache, static_popular_cache_batch)
from .cache_policies import (CACHE_POLICIES, cache_access, cache_rho,  # noqa: F401
                             cache_state_init, quantize_capacity,
                             quantize_sizes)
from .t2drl import (T2DRLCfg, episode_epsilon, episode_lr_scale,  # noqa: F401
                    episode_sigma, eval_t2drl, export_policy,
                    greedy_frame_cache, greedy_slot_action, run_episode,
                    run_eval, run_training, run_training_sharded,
                    t2drl_init, t2drl_init_batch, train_t2drl)
from .population import (PopMember, default_grid, population_schedules,  # noqa: F401
                         rank_population, train_population)
# Legacy per-method batch helpers now live behind the agent protocol as thin
# shims over repro.agents.vmap_agent.  Re-exported lazily (PEP 562): a module
# -level import would cycle when repro.agents is imported before repro.core.
_AGENT_COMPAT = ("d3pg_init_batch", "d3pg_update_batch",
                 "ddqn_init_batch", "ddqn_update_batch")


def __getattr__(name):
    if name in _AGENT_COMPAT:
        from repro.agents import compat
        return getattr(compat, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
