"""The paper's contribution: two-timescale model caching + resource
allocation for edge AIGC services (environment, D3PG, DDQN, baselines,
T2DRL driver)."""
from .env import (EnvCfg, EnvState, ModelParams, env_reset,  # noqa: F401
                  env_new_frame, env_step_slot, make_models, observe,
                  slot_metrics, slot_reward)
from .quality import tv_quality, gen_delay  # noqa: F401
from .ddqn import DDQNCfg, amend_caching, ddqn_act, ddqn_init, ddqn_update  # noqa: F401
from .d3pg import (D3PGCfg, actor_act, amend_actions, critic_q, d3pg_init,  # noqa: F401
                   d3pg_update, make_actor_schedule)
from .baselines import (GACfg, ga_allocate, random_cache, rcars_allocate,  # noqa: F401
                        static_popular_cache)
from .t2drl import (T2DRLCfg, eval_t2drl, run_episode, t2drl_init,  # noqa: F401
                    train_t2drl)
