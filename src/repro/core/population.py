"""Population-based hyperparameter sweeps over the fused independent core.

A *population* is B independent learners trained in ONE compiled
``run_training`` call (DESIGN.md §13) where each member carries its own
hyperparameters — epsilon/sigma exploration schedules, actor/critic/DDQN
learning rates, and the beyond-paper ``shape_hit`` reward-shaping
coefficient — delivered as per-member ``(E, B)`` schedule arrays through the
``pop`` argument of :func:`repro.core.t2drl.run_training`.

Knobs that are jit-STATIC (they change the compiled program — today only
``updates_per_slot``) cannot vary inside one call; :func:`train_population`
groups members by their static fields and runs one compile per group, so a
sweep mixing ``updates_per_slot`` values costs one compile per distinct
value, not per member.

The sweep protocol (``benchmarks/bench_population.py``,
``scripts/sweep_population.py``): train every member, greedily evaluate each
(``run_eval``: eps = sigma = 0, no updates), rank by mean evaluation
utility, and report the best member against the training-free RCARS
baseline on the same environment draw.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .t2drl import (T2DRLCfg, episode_epsilon, episode_lr_scale,
                    episode_sigma, run_eval, run_training, t2drl_init_batch)


@dataclasses.dataclass(frozen=True)
class PopMember:
    """One population member: hyperparameter overrides on a base T2DRLCfg.

    ``None`` means "inherit the base config's value".  All fields except
    ``updates_per_slot`` are dynamic (per-member schedule arrays — members
    differing only in them share ONE compile); ``updates_per_slot`` is
    jit-static and defines the member's compile group.

    ``name`` is a free-form label for leaderboards; auto-derived from the
    overridden fields when empty.
    """
    eps_start: Optional[float] = None
    eps_end: Optional[float] = None
    eps_decay_episodes: Optional[int] = None
    eps_schedule: Optional[str] = None
    lr_actor: Optional[float] = None
    lr_critic: Optional[float] = None
    lr_ddqn: Optional[float] = None
    lr_schedule: Optional[str] = None
    lr_warmdown_episodes: Optional[int] = None
    shape_hit: float = 0.0
    updates_per_slot: Optional[int] = None
    name: str = ""

    def label(self) -> str:
        if self.name:
            return self.name
        parts = [f"{f.name}={getattr(self, f.name)}"
                 for f in dataclasses.fields(self)
                 if f.name not in ("name", "shape_hit")
                 and getattr(self, f.name) is not None]
        if self.shape_hit:
            parts.append(f"shape_hit={self.shape_hit}")
        return ",".join(parts) if parts else "base"

    def member_cfg(self, cfg: T2DRLCfg) -> T2DRLCfg:
        """The base config with this member's *schedule-shaping* overrides
        applied — used only to materialize per-episode arrays; the static
        program stays the group's."""
        overrides = {f.name: getattr(self, f.name)
                     for f in dataclasses.fields(self)
                     if f.name not in ("name", "shape_hit")
                     and getattr(self, f.name) is not None}
        return dataclasses.replace(cfg, **overrides)


def population_schedules(cfg: T2DRLCfg, members: Sequence[PopMember],
                         episodes: int):
    """Materialize per-member hyperparameter schedules as a ``pop`` dict.

    Returns ``{key: (E, B)}`` arrays over ``E = episodes`` and
    ``B = len(members)`` — each column is that member's own
    epsilon/sigma/LR schedule, computed by the SAME schedule functions the
    driver uses for scalar configs (``episode_epsilon`` etc.), so a
    single-member population reproduces the plain ``run_training``
    schedules exactly."""
    e = jnp.arange(episodes, dtype=jnp.float32)
    cols = {k: [] for k in ("eps", "sigma", "lr_actor", "lr_critic",
                            "lr_ddqn", "shape_hit")}
    for m in members:
        mc = m.member_cfg(cfg)
        scale = episode_lr_scale(mc, e)
        cols["eps"].append(episode_epsilon(mc, e))
        cols["sigma"].append(episode_sigma(mc, e))
        cols["lr_actor"].append(mc.lr_actor * scale)
        cols["lr_critic"].append(mc.lr_critic * scale)
        cols["lr_ddqn"].append(jnp.full((episodes,), mc.lr_ddqn,
                                        jnp.float32))
        cols["shape_hit"].append(jnp.full((episodes,), m.shape_hit,
                                          jnp.float32))
    return {k: jnp.stack(v, axis=1) for k, v in cols.items()}   # (E, B)


def _group_members(cfg: T2DRLCfg, members: Sequence[PopMember]):
    """Split members into compile groups by their jit-static fields.
    Yields ``(group_cfg, [(index, member), ...])`` preserving input order
    within each group."""
    def static_key(m: PopMember):
        return (m.updates_per_slot if m.updates_per_slot is not None
                else cfg.updates_per_slot,)

    order = sorted(enumerate(members), key=lambda im: static_key(im[1]))
    for key, grp in itertools.groupby(order, key=lambda im: static_key(im[1])):
        group_cfg = dataclasses.replace(cfg, updates_per_slot=key[0])
        yield group_cfg, list(grp)


def train_population(cfg: T2DRLCfg, members: Sequence[PopMember], *,
                     episodes: int, eval_episodes: int = 4,
                     seed: int = 0, share_models: bool = True,
                     log=None):
    """Train and evaluate a population; one compiled call per static group.

    Every member trains for ``episodes`` episodes in fused independent mode
    (``cfg`` must have ``policy="independent"``; ``independent_impl`` is
    forced to ``"fused"``), then is greedily evaluated for
    ``eval_episodes`` episodes.  ``share_models=True`` broadcasts one model
    zoo to every member so the sweep compares hyperparameters, not
    environment draws (per-member env/episode PRNG streams still differ —
    average over eval episodes to compare members).

    Returns a list of result dicts (input order), each with the member's
    ``label``, training ``history`` (per-episode scalars), and mean eval
    stats; plus a ``groups`` summary of compiles.
    """
    cfg = dataclasses.replace(cfg, policy="independent",
                              independent_impl="fused")
    results = [None] * len(members)
    groups = []
    for group_cfg, grp in _group_members(cfg, members):
        idxs = [i for i, _ in grp]
        ms = [m for _, m in grp]
        B = len(ms)
        key = jax.random.PRNGKey(seed)
        k_init, k_train = jax.random.split(key)
        ts = t2drl_init_batch(k_init, group_cfg, B,
                              share_models=share_models)
        pop = population_schedules(group_cfg, ms, episodes)
        if log:
            log(f"group updates_per_slot={group_cfg.updates_per_slot}: "
                f"{B} members x {episodes} episodes, one compile")
        ts, hist = run_training(ts, group_cfg, k_train,
                                jnp.arange(episodes), pop=pop)
        ev = run_eval(ts, group_cfg, jax.random.fold_in(key, 10_000),
                      jnp.arange(eval_episodes))
        ev_mean = {k: jnp.mean(v, axis=0) for k, v in ev.items()}  # (B,)
        for j, (i, m) in enumerate(zip(idxs, ms)):
            results[i] = {
                "label": m.label(),
                "member": m,
                "history": {k: v[:, j] for k, v in hist.items()},
                "eval": {k: float(ev_mean[k][j]) for k in ev_mean},
            }
        groups.append({"updates_per_slot": group_cfg.updates_per_slot,
                       "members": [m.label() for m in ms]})
    return results, groups


def rank_population(results, *, by: str = "utility", descending=None):
    """Order member results best-first by a mean-eval stat.  Stats where
    lower is better (``delay``, ``deadline_viol``, ``storage_viol``) sort
    ascending unless overridden."""
    if descending is None:
        descending = by not in ("delay", "deadline_viol", "storage_viol")
    return sorted(results, key=lambda r: r["eval"][by], reverse=descending)


def default_grid(*, updates_per_slot: Sequence[int] = (1,)) -> list:
    """The stock 16-member sweep grid (ISSUE 6): eps schedule x actor/critic
    LR x DDQN LR x reward shaping, optionally crossed with static
    ``updates_per_slot`` groups.  With the default single group the whole
    grid trains in ONE compiled call."""
    grid = []
    for ups in updates_per_slot:
        for eps_start, eps_sched in ((1.0, "linear"), (0.6, "cosine")):
            for lr_a, lr_c in ((1e-4, 1e-3), (3e-4, 3e-3)):
                for lr_q in (1e-3, 3e-3):
                    for shape in (0.0, 0.5):
                        grid.append(PopMember(
                            eps_start=eps_start, eps_schedule=eps_sched,
                            lr_actor=lr_a, lr_critic=lr_c, lr_ddqn=lr_q,
                            shape_hit=shape,
                            updates_per_slot=(ups if len(updates_per_slot)
                                              > 1 else None),
                            name=(f"eps{eps_start}-{eps_sched}_a{lr_a}"
                                  f"_c{lr_c}_q{lr_q}_s{shape}"
                                  + (f"_u{ups}" if len(updates_per_slot) > 1
                                     else ""))))
    return grid
