"""T2DRL — the paper's Algorithm 1: outer long-timescale caching (frames) +
inner short-timescale allocation (slots), fully jitted per episode.

The driver is written against the agent protocol (``repro.agents``,
DESIGN.md §12): a per-slot allocator Agent and a per-frame cacher Agent,
selected once by ``allocator``/``cacher``, covering the paper's benchmarks:

  T2DRL             allocator="d3pg",  cacher="ddqn"
  DDPG-based T2DRL  allocator="ddpg",  cacher="ddqn"
  SCHRS             allocator="schrs", cacher="static"
  RCARS             allocator="rcars", cacher="random"

plus the classical cache-hierarchy baselines (DESIGN.md §14):
cacher in {"lru", "lfu", "lru-ghost", "arc"} — stateful non-learned
cachers whose array state machine lives in the ``"cache"`` TrainState
slot and advances once per frame on the frame's request stream
(``Agent.step_frame``), combinable with any allocator.

Vectorized training core (DESIGN.md §6): the per-episode logic lives in
``_episode_core`` (single env, optionally user-masked).  ``run_training``
vmaps it over a leading batch axis of B independent edge cells — each with
its own model zoo, replay buffers, agent parameters, and popularity /
location Markov chains — and scans over episodes, so an entire multi-seed,
multi-episode run is ONE compiled call.  ``run_episode`` remains the public
single-env entry point, and B=1 bypasses vmap entirely, so the legacy path
is reproduced exactly (cell 0 of any batch uses the same keys as a legacy
single-env run with the same seed).

Compiled-path engineering (DESIGN.md §12): scan carries hold only what a
timescale mutates (agent state, env, carried observation — replay buffers
are scan constants within a frame), replay writes are batched once per
frame, epsilon/sigma/LR schedules are precomputed scan inputs, the train
state is donated through ``run_training``, and on CPU the episode programs
are compiled with the sequential (non-thunk) XLA runtime, which executes
these long two-level scans measurably faster.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

# only repro.agents.base (which has no repro.core dependency) is safe to
# import at module level; the factory dispatch is imported lazily inside
# _agents so either package may be imported first without a cycle
from repro.agents.base import FrameObs, SlotObs, vmap_agent
from repro.obs.profiling import record_compile
from repro.obs.taps import (ObsCfg, broadcast_diag, combine_updates,
                            reduce_update_diag)
from repro.obs.writer import progress_line
from .baselines import GACfg
from .buffers import (buffer_add, buffer_add_batch, buffer_add_many,
                      buffer_add_many_batch, buffer_add_many_stacked,
                      buffer_init, buffer_occupancy, buffer_sample,
                      buffer_sample_batch, buffer_sample_stacked)
from .cache_policies import cache_state_init
from .d3pg import D3PGCfg, d3pg_init
from .ddqn import DDQNCfg, ddqn_init
from .env import (EnvCfg, EnvState, ModelParams, ScenarioSchedule,
                  env_advance_frame, env_reset, env_reset_batch,
                  env_set_cache, env_step_slot, make_models, make_user_masks,
                  masked_mean, observe, schedule_frame_P, schedule_slot_mod)


@dataclasses.dataclass(frozen=True)
class T2DRLCfg:
    """Static configuration of the two-timescale driver (jit-static).

    Attributes
    ----------
    env : EnvCfg
        Environment configuration (scenario transforms replace this).
    allocator : {"d3pg", "ddpg", "schrs", "rcars"}
        Short-timescale per-slot resource allocator.
    cacher : {"ddqn", "static", "random", "lru", "lfu", "lru-ghost", "arc"}
        Long-timescale per-frame caching agent.  The last four are the
        classical cache-hierarchy baselines (DESIGN.md §14): stateful
        non-learned array state machines advanced per frame by the
        request stream via ``Agent.step_frame``.
    policy : {"independent", "shared"}
        Vector-env mode (DESIGN.md §6): B independent learners vs one
        learner fed by all cells.
    independent_impl : {"fused", "vmap"}
        How B > 1 independent learners execute (DESIGN.md §13).
        ``"fused"`` (default) runs all B learners as ONE batched program —
        stacked einsum contractions, a fused optimizer pass, scalar
        (branch-skipping) update gates — and is what population training
        requires.  ``"vmap"`` is the legacy ``jax.vmap`` of the single-env
        episode, kept as the bit-identity reference the fused path is
        pinned against (``tests/test_fused.py``).  B == 1 always runs the
        unbatched legacy program.
    episodes : int
        Default training episode count (paper: 500).
    warmup : int
        Stored slot transitions before D3PG minibatch updates begin.
    eps_start, eps_end, eps_decay_episodes : float, float, int
        DDQN epsilon-greedy schedule over episodes.
    eps_schedule : {"linear", "cosine"}
        Epsilon (and exploration-sigma) decay shape over
        ``eps_decay_episodes`` — "linear" is the paper's schedule;
        "cosine" holds exploration longer before annealing (DESIGN.md §12).
    lr_actor, lr_critic, lr_ddqn : float
        Adam learning rates (paper default 1e-6; see DESIGN.md §8 for the
        tuned CI-scale values).
    lr_schedule : {"const", "linear", "cosine"}
        Actor/critic learning-rate warmdown over ``lr_warmdown_episodes``
        episodes, from the configured rate down to ``lr_end_scale`` times
        it.  "const" (default) reproduces the fixed-rate paper setup
        exactly; schedules are materialized as precomputed per-episode
        scan inputs (no python re-entry).
    lr_warmdown_episodes : int
        Horizon of the LR warmdown (ignored for ``lr_schedule="const"``).
    lr_end_scale : float
        Final LR as a fraction of the initial rate.
    updates_per_slot : int
        Gradient steps per rollout slot once past warmup (default 1 — the
        paper's 1:1 update:data ratio, using the exact legacy per-slot
        key stream).  Values > 1 run an inner ``lax.scan`` of minibatch
        updates per slot, letting long-horizon runs trade rollout steps
        for gradient steps without re-entering Python (DESIGN.md §12).
    L : int
        Diffusion-actor denoising steps (paper Fig. 6a).
    seed : int
        Root PRNG seed for init and episode keys.
    ga : GACfg
        Genetic-algorithm parameters for the SCHRS baseline.
    obs : ObsCfg
        In-scan telemetry switches (DESIGN.md §15).  The default
        (``enabled=False``) keeps every tap site a python-level no-op, so
        the episode cores compile the exact pre-telemetry program; with
        telemetry on, per-update learner diagnostics and replay occupancy
        ride the history dict under ``"diag/..."`` keys.
    """
    env: EnvCfg = EnvCfg()
    allocator: str = "d3pg"     # d3pg | ddpg | schrs | rcars
    cacher: str = "ddqn"        # ddqn | static | random
    policy: str = "independent"  # vector-env mode: independent | shared
    independent_impl: str = "fused"  # B>1 independent learners: fused | vmap
    episodes: int = 500
    warmup: int = 200           # slot transitions before D3PG updates
    eps_start: float = 1.0      # DDQN epsilon-greedy schedule (per episode)
    eps_end: float = 0.05
    eps_decay_episodes: int = 300
    eps_schedule: str = "linear"    # linear | cosine
    lr_actor: float = 1e-6      # paper default; benchmarks also run tuned lr
    lr_critic: float = 1e-6
    lr_ddqn: float = 1e-6
    lr_schedule: str = "const"      # const | linear | cosine
    lr_warmdown_episodes: int = 0
    lr_end_scale: float = 0.1
    updates_per_slot: int = 1
    L: int = 5                  # D3PG denoising steps
    seed: int = 0
    ga: GACfg = GACfg()
    obs: ObsCfg = ObsCfg()      # telemetry taps (DESIGN.md §15)

    def d3pg_cfg(self) -> D3PGCfg:
        return D3PGCfg(state_dim=self.env.state_dim,
                       action_dim=self.env.action_dim, L=self.L,
                       actor_kind="mlp" if self.allocator == "ddpg"
                       else "diffusion",
                       lr_actor=self.lr_actor, lr_critic=self.lr_critic)

    def ddqn_cfg(self) -> DDQNCfg:
        return DDQNCfg(M=self.env.M, J=len(self.env.gammas),
                       lr=self.lr_ddqn)


def _agents(cfg: T2DRLCfg):
    """The (allocator, cacher) Agent pair for ``cfg`` — the single place
    method names are dispatched (DESIGN.md §12)."""
    # lazy: repro.agents.{allocators,cachers} import repro.core submodules,
    # so a module-level import here would cycle when repro.agents loads first
    from repro.agents.allocators import make_allocator
    from repro.agents.cachers import make_cacher
    if cfg.updates_per_slot < 1:
        raise ValueError("updates_per_slot must be >= 1")
    diag = cfg.obs.learner_on
    return (make_allocator(cfg.allocator, cfg.env, cfg.d3pg_cfg(), cfg.ga,
                           diag=diag),
            make_cacher(cfg.cacher, cfg.ddqn_cfg(), cfg.env, diag=diag))


def t2drl_init(key, cfg: T2DRLCfg):
    """Fresh unified train-state pytree (DESIGN.md §12).

    The layout is FIXED regardless of method — ``{"models", "d3pg",
    "ddqn", "ebuf", "fbuf", "cache"}`` — so vector-env squeeze/expand,
    checkpoints (``repro.checkpoint.save_train_state``), and fleet policy
    export never branch on agent kinds; non-learned methods simply never
    read their (still initialized) learner slots.  ``"cache"`` is the
    classical-cacher array state machine (DESIGN.md §14) — keyless init,
    so adding it left every PRNG stream untouched."""
    km, kq, kd = jax.random.split(key, 3)
    env = cfg.env
    models = make_models(km, env)
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    S, A, U, M = env.state_dim, env.action_dim, env.U, env.M
    slot_item = {
        "s": jnp.zeros(S), "a": jnp.zeros(A), "r": jnp.float32(0.0),
        "s1": jnp.zeros(S), "req": jnp.zeros(U, jnp.int32),
        "rho": jnp.zeros(M), "req1": jnp.zeros(U, jnp.int32),
        "rho1": jnp.zeros(M),
    }
    frame_item = {"s": jnp.int32(0), "a": jnp.int32(0),
                  "r": jnp.float32(0.0), "s1": jnp.int32(0)}
    return {
        "models": models,
        "d3pg": d3pg_init(kd, d3),
        "ddqn": ddqn_init(kq, dq),
        "ebuf": buffer_init(d3.buffer, slot_item),
        "fbuf": buffer_init(dq.buffer, frame_item),
        "cache": cache_state_init(M),
    }


def _batch_keys(key, num_envs: int):
    """Per-cell keys with the invariant cell0 == ``key``: cell 0 of any
    batch replays the legacy single-env run for the same seed."""
    if num_envs == 1:
        return key[None]
    return jnp.stack([key] + [jax.random.fold_in(key, i)
                              for i in range(1, num_envs)])


def t2drl_init_batch(key, cfg: T2DRLCfg, num_envs: int, *,
                     share_models: bool = False):
    """Train state for B parallel cells as one pytree.  Models and replay
    buffers always carry a leading (B,) axis; with ``cfg.policy ==
    "independent"`` the agent parameters do too (B fully independent
    seeds), while ``"shared"`` keeps ONE set of agent parameters (cell 0's
    init) learning from all cells' experience.

    Each cell draws its own model zoo (heterogeneous across the batch);
    ``share_models=True`` broadcasts cell 0's zoo to every cell instead
    (pure multi-seed variance studies on one scenario)."""
    if cfg.policy not in ("independent", "shared"):
        raise ValueError(f"unknown policy {cfg.policy!r}; "
                         "expected 'independent' or 'shared'")
    if num_envs < 1:
        raise ValueError("num_envs must be >= 1")
    ts = jax.vmap(lambda k: t2drl_init(k, cfg))(_batch_keys(key, num_envs))
    if share_models:
        ts["models"] = jax.tree.map(
            lambda x: jnp.repeat(x[:1], num_envs, axis=0), ts["models"])
    if cfg.policy == "shared":
        ts["d3pg"] = jax.tree.map(lambda x: x[0], ts["d3pg"])
        ts["ddqn"] = jax.tree.map(lambda x: x[0], ts["ddqn"])
    return ts


# -- exploration / learning-rate schedules (precomputed scan inputs) ----------

def _eps_frac(cfg: T2DRLCfg, episode):
    """Annealing fraction in [0, 1] under ``cfg.eps_schedule`` (validated —
    an unknown name must raise, not silently fall back to linear)."""
    frac = jnp.clip(episode / max(cfg.eps_decay_episodes, 1), 0.0, 1.0)
    if cfg.eps_schedule == "cosine":
        return 0.5 * (1.0 - jnp.cos(jnp.pi * frac))
    if cfg.eps_schedule != "linear":
        raise ValueError(f"unknown eps_schedule {cfg.eps_schedule!r}; "
                         "expected 'linear' or 'cosine'")
    return frac


def episode_epsilon(cfg: T2DRLCfg, episode):
    """DDQN epsilon at ``episode`` (scalar or array of episode indices)."""
    frac = _eps_frac(cfg, episode)
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


def episode_sigma(cfg: T2DRLCfg, episode):
    """Exploration-noise schedule: decays from explore_sigma to 0.02 on the
    same schedule as epsilon; zero for the non-learned allocators."""
    episode = jnp.asarray(episode, jnp.float32)
    if cfg.allocator not in ("d3pg", "ddpg"):
        return jnp.zeros_like(episode)
    d3 = cfg.d3pg_cfg()
    frac = _eps_frac(cfg, episode)
    return (d3.explore_sigma * (1.0 - frac) + 0.02 * frac).astype(jnp.float32)


def episode_lr_scale(cfg: T2DRLCfg, episode):
    """Actor/critic LR warmdown factor at ``episode``: 1 -> lr_end_scale
    over ``lr_warmdown_episodes`` (identically 1 for "const")."""
    episode = jnp.asarray(episode, jnp.float32)
    if cfg.lr_schedule == "const":
        return jnp.ones_like(episode)
    if cfg.lr_schedule not in ("linear", "cosine"):
        raise ValueError(f"unknown lr_schedule {cfg.lr_schedule!r}; "
                         "expected 'const', 'linear' or 'cosine'")
    if cfg.lr_warmdown_episodes < 1:
        # silently clamping would collapse the LR to lr_end_scale right
        # after episode 0 — an instant cliff, not a warmdown
        raise ValueError(f"lr_schedule={cfg.lr_schedule!r} requires "
                         "lr_warmdown_episodes >= 1")
    frac = jnp.clip(episode / cfg.lr_warmdown_episodes, 0.0, 1.0)
    if cfg.lr_schedule == "cosine":
        frac = 0.5 * (1.0 - jnp.cos(jnp.pi * frac))
    return 1.0 + (cfg.lr_end_scale - 1.0) * frac


def _update_aux(step, mask):
    """Reserved minibatch auxiliaries for Agent.update (DESIGN.md §12):
    the active-user mask and any schedule-driven learning rates."""
    aux = {}
    if mask is not None:
        aux["mask"] = mask
    if "lr_actor" in step:
        aux["lr_actor"] = step["lr_actor"]
        aux["lr_critic"] = step["lr_critic"]
    return aux


def _slot_updates(alloc, cfg: T2DRLCfg, state, ks, step, aux_mask, sample,
                  tap: bool = False):
    """``updates_per_slot`` sample+update steps of the allocator, shared by
    both episode cores (``sample(key) -> minibatch`` is the only part that
    differs).  N == 1 consumes ``ks[2]``/``ks[3]`` directly — the exact
    legacy per-slot key stream; N > 1 runs an inner ``lax.scan`` over
    ``split(ks[2], N)`` / ``split(ks[3], N)`` (DESIGN.md §12).

    ``tap=True`` (telemetry, DESIGN.md §15) returns ``(state, metrics)`` —
    the update's diagnostics dict, combined over the N inner updates —
    instead of just ``state``."""
    def one(state, kk):
        k_samp, k_upd = kk
        batch = sample(k_samp)
        state, m = alloc.update(state,
                                {**batch, **_update_aux(step, aux_mask)},
                                k_upd)
        return state, (m if tap else None)
    if cfg.updates_per_slot == 1:
        state, m = one(state, (ks[2], ks[3]))
        return (state, m) if tap else state
    state, ms = jax.lax.scan(
        one, state, (jax.random.split(ks[2], cfg.updates_per_slot),
                     jax.random.split(ks[3], cfg.updates_per_slot)))
    return (state, combine_updates(ms)) if tap else state


def _slot_updates_stacked(alloc, cfg: T2DRLCfg, state, ks, step, aux_mask,
                          sample, tap: bool = False):
    """Fused-core counterpart of :func:`_slot_updates`: ``alloc`` is the
    stacked agent, ``ks`` the per-cell key quads ``(B, 4, 2)``, and
    ``sample(keys) -> minibatch`` draws every cell's own minibatch
    (``(B, n, ...)`` leaves) in one fused gather.  Key derivations mirror
    the per-cell ``_slot_updates`` exactly (DESIGN.md §13).  ``tap=True``
    returns ``(state, metrics)`` with per-learner ``(B,)``-leading
    diagnostics."""
    def one(state, kk):
        k_samp, k_upd = kk                  # (B, 2) each
        batch = sample(k_samp)
        state, m = alloc.update(state,
                                {**batch, **_update_aux(step, aux_mask)},
                                k_upd)
        return state, (m if tap else None)
    if cfg.updates_per_slot == 1:
        state, m = one(state, (ks[:, 2], ks[:, 3]))
        return (state, m) if tap else state
    split_n = lambda k: jax.random.split(k, cfg.updates_per_slot)
    state, ms = jax.lax.scan(
        one, state,
        (jnp.moveaxis(jax.vmap(split_n)(ks[:, 2]), 1, 0),
         jnp.moveaxis(jax.vmap(split_n)(ks[:, 3]), 1, 0)))
    return (state, combine_updates(ms)) if tap else state


# -- episode cores ------------------------------------------------------------

def _episode_core(ts, cfg: T2DRLCfg, key, step, *, train: bool = True,
                  mask=None, mods: Optional[ScenarioSchedule] = None):
    """One episode of Algorithm 1 for a single env.

    ``step`` is the per-episode schedule dict (``eps``, ``sigma``, optional
    ``lr_*``); ``mask`` an optional (U,) 0/1 vector of active users
    (heterogeneous-population cells); ``mods`` an optional per-episode
    ScenarioSchedule (unbatched leaves) whose slices are fed to the env at
    every draw (DESIGN.md §9).  The PRNG stream is identical to the
    pre-protocol driver; replay writes are batched once per frame, so a
    slot's minibatch samples from the buffer as of the frame start
    (DESIGN.md §12).  Returns (ts, stats)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    alloc, cacher = _agents(cfg)
    stateful = cacher.step_frame is not None   # classical cacher (§14);
    # python-static, so stateless methods compile the exact pre-§14 program
    # telemetry taps (DESIGN.md §15): python-static, so with telemetry off
    # (the default) the episode traces the exact pre-telemetry program
    tap_a = train and alloc.diag_zero is not None
    tap_c = train and cacher.diag_zero is not None
    models: ModelParams = ts["models"]
    cap_e = d3.buffer
    k_env, key = jax.random.split(key)
    env = env_reset(k_env, env_cfg, schedule_slot_mod(mods, 0))

    def slot_stats(r, m):
        return {"r": r, "hit": masked_mean(m["cached"], mask),
                "G": masked_mean(m["G"], mask),
                "delay": masked_mean(m["d_tl"], mask),
                "quality": masked_mean(m["quality"], mask),
                "viol": masked_mean(
                    (m["d_tl"] > env_cfg.tau).astype(jnp.float32), mask)}

    def frame_step(carry, xs):
        k_frame, t = xs                # t: frame index into the schedule
        if stateful:
            carry, cstate = carry[:-1], carry[-1]
        if alloc.learns:
            alloc_state, ebuf, env = carry
        else:
            alloc_state, (env,) = ts["d3pg"], carry
        kf = jax.random.split(k_frame, 3)
        env = env_advance_frame(env, env_cfg, schedule_frame_P(mods, t),
                                schedule_slot_mod(mods, t * env_cfg.K))
        gamma_t = env.gamma_idx
        a_int, rho = cacher.act(cstate if stateful else ts["ddqn"],
                                FrameObs(gamma_t, models), kf[0], step)
        env = env_set_cache(env, rho)
        size0 = ebuf["size"] if alloc.learns else None

        def slot_step(carry, xs):
            k_slot, g = xs             # g: global slot index t*K + k
            if alloc.learns:
                alloc_state, env, s = carry
            else:
                alloc_state, (env,), s = ts["d3pg"], carry, None
            ks = jax.random.split(k_slot, 4)
            b, xi = alloc.act(alloc_state, SlotObs(s, env, models, mask),
                              ks[:2], step)
            env1, r, m = env_step_slot(env, env_cfg, models, b, xi, mask,
                                       schedule_slot_mod(mods, g + 1))
            if not alloc.learns:
                out = slot_stats(r, m)
                # a stateful cacher needs the frame's served requests
                # (env.req, pre-advance) replayed at frame end
                return (env1,), ((out, env.req) if stateful else out)
            s1 = observe(env1, env_cfg, models, mask)
            item = {"s": s, "a": jnp.concatenate([b, xi]), "r": r, "s1": s1,
                    "req": env.req, "rho": env.rho, "req1": env1.req,
                    "rho1": env1.rho}
            if train:
                # transitions stored so far = frame-start size + slot count
                # (the write itself is batched at frame end); sampling past
                # warmup therefore sees the buffer as of the frame start
                k_in = g - t * env_cfg.K
                stored = jnp.minimum(size0 + k_in + 1, cap_e)
                pred = (stored > cfg.warmup) & (size0 > 0)
                if tap_a:
                    alloc_state, adiag = jax.lax.cond(
                        pred,
                        lambda st: _slot_updates(
                            alloc, cfg, st, ks, step, mask,
                            lambda k: buffer_sample(ebuf, k, d3.batch),
                            tap=True),
                        lambda st: (st, alloc.diag_zero()), alloc_state)
                    return ((alloc_state, env1, s1),
                            (slot_stats(r, m), item,
                             (adiag, pred.astype(jnp.float32))))
                alloc_state = jax.lax.cond(
                    pred,
                    lambda st: _slot_updates(
                        alloc, cfg, st, ks, step, mask,
                        lambda k: buffer_sample(ebuf, k, d3.batch)),
                    lambda st: st, alloc_state)
            return (alloc_state, env1, s1), (slot_stats(r, m), item)

        g_idx = t * env_cfg.K + jnp.arange(env_cfg.K)
        slot_keys = jax.random.split(kf[1], env_cfg.K)
        reqs = adiag = None
        if alloc.learns:
            s = observe(env, env_cfg, models, mask)
            if tap_a:
                (alloc_state, env, _), (stats, items, adiag) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            else:
                (alloc_state, env, _), (stats, items) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            ebuf = buffer_add_many(ebuf, items)
            reqs = items["req"]                           # (K, U)
        elif stateful:
            (env,), (stats, reqs) = jax.lax.scan(slot_step, (env,),
                                                 (slot_keys, g_idx))
        else:
            (env,), stats = jax.lax.scan(slot_step, (env,),
                                         (slot_keys, g_idx))
        if stateful:
            cstate = cacher.step_frame(cstate, reqs, models, mask)
        # frame reward (32): average slot reward minus storage penalty
        # (erratum-corrected sign — see DESIGN.md §8)
        storage_viol = (jnp.sum(rho * models.c) > env_cfg.C).astype(jnp.float32)
        r_frame = jnp.mean(stats["r"]) - storage_viol * env_cfg.Xi
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": stats, "storage_viol": storage_viol}
        if tap_a:
            out["adiag"] = adiag               # ((K, ...) metrics, (K,) did)
        carry = ((alloc_state, ebuf, env) if alloc.learns else (env,))
        if stateful:
            carry = carry + (cstate,)
        return carry, out

    frame_xs = (jax.random.split(key, env_cfg.T), jnp.arange(env_cfg.T))
    init = ((ts["d3pg"], ts["ebuf"], env) if alloc.learns else (env,))
    if stateful:
        init = init + (ts["cache"],)
    final, frames = jax.lax.scan(frame_step, init, frame_xs)
    cache_state = final[-1] if stateful else ts["cache"]
    if stateful:
        final = final[:-1]
    if alloc.learns:
        alloc_state, ebuf, env = final
    else:
        (env,) = final
        alloc_state, ebuf = ts["d3pg"], ts["ebuf"]

    # DDQN frame transitions: (gamma_t, a_t, r_t, gamma_{t+1}) for t < T-1
    cacher_state, fbuf = ts["ddqn"], ts["fbuf"]
    cdiag = None
    if cacher.learns and train:
        def add_and_update(carry, t):
            cacher_state, fbuf = carry
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add(fbuf, item)
            pred = fbuf["size"] > dq.batch

            def do_update(cs):
                kb = jax.random.fold_in(key, t)
                batch = buffer_sample(fbuf, kb, dq.batch)
                cs, m = cacher.update(cs, batch, kb)
                return (cs, m) if tap_c else cs
            if tap_c:
                cacher_state, m = jax.lax.cond(
                    pred, do_update,
                    lambda cs: (cs, cacher.diag_zero()), cacher_state)
                return ((cacher_state, fbuf),
                        (m, pred.astype(jnp.float32)))
            cacher_state = jax.lax.cond(pred, do_update,
                                        lambda cs: cs, cacher_state)
            return (cacher_state, fbuf), None
        (cacher_state, fbuf), cdiag = jax.lax.scan(
            add_and_update, (cacher_state, fbuf),
            jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]
    stats = {
        "episode_reward": jnp.sum(slot["r"]),
        "mean_reward": jnp.mean(slot["r"]),
        "hit_ratio": jnp.mean(slot["hit"]),
        "utility": jnp.mean(slot["G"]),
        "delay": jnp.mean(slot["delay"]),
        "quality": jnp.mean(slot["quality"]),
        "deadline_viol": jnp.mean(slot["viol"]),
        "storage_viol": jnp.mean(frames["storage_viol"]),
    }
    if tap_a:
        stats.update(reduce_update_diag(*frames["adiag"], prefix="diag/"))
    if tap_c:
        stats.update(reduce_update_diag(*cdiag, prefix="diag/ddqn_"))
    if train and cfg.obs.replay_on:
        occ = {**buffer_occupancy(ebuf, "ebuf", capacity=d3.buffer),
               **buffer_occupancy(fbuf, "fbuf", capacity=dq.buffer)}
        stats.update({"diag/" + k: v for k, v in occ.items()})
    ts = {"models": models, "d3pg": alloc_state, "ddqn": cacher_state,
          "ebuf": ebuf, "fbuf": fbuf, "cache": cache_state}
    return ts, stats


def _batch_mean(x, masks=None):
    """Per-env mean over the trailing user axis; masks: (B, U) or None."""
    if masks is None:
        return jnp.mean(x, axis=-1)
    return jnp.sum(x * masks, axis=-1) / jnp.maximum(
        jnp.sum(masks, axis=-1), 1.0)


def _episode_core_shared(ts, cfg: T2DRLCfg, keys, step, *,
                         train: bool = True, masks=None,
                         mods: Optional[ScenarioSchedule] = None):
    """One episode in shared-learner vector-env mode: B cells roll out in
    lockstep feeding per-cell replay buffers, and ONE shared policy takes a
    single optimizer step per slot on a fixed-size minibatch pooled evenly
    across the cells' buffers.  Per-step learner cost is independent of B —
    the standard vector-env trade (update:data ratio scales as 1/B).
    ``mods``: optional ScenarioSchedule with per-cell (B,)-leading leaves.
    Returns (ts, stats) with per-cell stats of shape (B,)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    alloc, cacher = _agents(cfg)
    stateful = cacher.step_frame is not None   # classical cacher (§14)
    # telemetry taps (DESIGN.md §15): the shared learner takes ONE pooled
    # update per slot/frame, so its diagnostics are scalars — broadcast to
    # (B,) at episode end to match the per-cell stats layout
    tap_a = train and alloc.diag_zero is not None
    tap_c = train and cacher.diag_zero is not None
    models: ModelParams = ts["models"]
    cap_e = d3.buffer
    B = keys.shape[0]
    k_env = jax.vmap(lambda k: jax.random.split(k)[0])(keys)
    key = jax.random.split(keys[0])[1]     # driver key (frames, updates)
    env = env_reset_batch(k_env, env_cfg, schedule_slot_mod(mods, 0))
    n_slot = max(1, d3.batch // B)         # per-cell slice of the minibatch
    n_frame = max(1, dq.batch // B)
    row_masks = (None if masks is None
                 else jnp.repeat(masks, n_slot, axis=0))
    act = alloc.batch_act or alloc.act
    cact = cacher.batch_act or cacher.act

    def pool(batch_be):
        """(B, n, ...) per-cell samples -> one (B*n, ...) minibatch."""
        return jax.tree.map(
            lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]),
            batch_be)

    def observe_b(env):
        return jax.vmap(lambda e, m, mk: observe(e, env_cfg, m, mk))(
            env, models, masks)                               # (B, S)

    def slot_stats(r, m):
        return {"r": r, "hit": _batch_mean(m["cached"], masks),
                "G": _batch_mean(m["G"], masks),
                "delay": _batch_mean(m["d_tl"], masks),
                "quality": _batch_mean(m["quality"], masks),
                "viol": _batch_mean(
                    (m["d_tl"] > env_cfg.tau).astype(jnp.float32), masks)}

    def frame_step(carry, xs):
        k_frame, t = xs                # t: frame index into the schedule
        if stateful:
            carry, cstate = carry[:-1], carry[-1]
        if alloc.learns:
            alloc_state, ebuf, env = carry
        else:
            alloc_state, (env,) = ts["d3pg"], carry
        kf = jax.random.split(k_frame, 3)
        env = jax.vmap(lambda e, P, md: env_advance_frame(e, env_cfg, P, md))(
            env, schedule_frame_P(mods, t),
            schedule_slot_mod(mods, t * env_cfg.K))
        gamma_t = env.gamma_idx                               # (B,)
        a_int, rho = cact(cstate if stateful else ts["ddqn"],
                          FrameObs(gamma_t, models), kf[0], step)
        env = jax.vmap(env_set_cache)(env, rho)
        size0 = ebuf["size"] if alloc.learns else None        # (B,)

        def slot_step(carry, xs):
            k_slot, g = xs             # g: global slot index t*K + k
            if alloc.learns:
                alloc_state, env, s = carry
            else:
                alloc_state, (env,), s = ts["d3pg"], carry, None
            ks = jax.random.split(k_slot, 4)
            b, xi = act(alloc_state, SlotObs(s, env, models, masks),
                        ks[:2], step)
            env1, r, m = jax.vmap(
                lambda e, mo, bb, xx, mk, md: env_step_slot(
                    e, env_cfg, mo, bb, xx, mk, md))(
                env, models, b, xi, masks, schedule_slot_mod(mods, g + 1))
            if not alloc.learns:
                out = slot_stats(r, m)
                return (env1,), ((out, env.req) if stateful else out)
            s1 = observe_b(env1)
            item = {"s": s, "a": jnp.concatenate([b, xi], axis=-1), "r": r,
                    "s1": s1, "req": env.req, "rho": env.rho,
                    "req1": env1.req, "rho1": env1.rho}
            if train:
                k_in = g - t * env_cfg.K
                stored = jnp.sum(jnp.minimum(size0 + k_in + 1, cap_e))
                pred = (stored > cfg.warmup) & (jnp.min(size0) > 0)
                sample = lambda k: pool(buffer_sample_batch(
                    ebuf, jax.random.split(k, B), n_slot))
                if tap_a:
                    alloc_state, adiag = jax.lax.cond(
                        pred,
                        lambda st: _slot_updates(alloc, cfg, st, ks, step,
                                                 row_masks, sample, tap=True),
                        lambda st: (st, alloc.diag_zero()), alloc_state)
                    return ((alloc_state, env1, s1),
                            (slot_stats(r, m), item,
                             (adiag, pred.astype(jnp.float32))))
                alloc_state = jax.lax.cond(
                    pred,
                    lambda st: _slot_updates(alloc, cfg, st, ks, step,
                                             row_masks, sample),
                    lambda st: st, alloc_state)
            return (alloc_state, env1, s1), (slot_stats(r, m), item)

        g_idx = t * env_cfg.K + jnp.arange(env_cfg.K)
        slot_keys = jax.random.split(kf[1], env_cfg.K)
        reqs = adiag = None
        if alloc.learns:
            s = observe_b(env)
            if tap_a:
                (alloc_state, env, _), (stats, items, adiag) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            else:
                (alloc_state, env, _), (stats, items) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            # one batched write per frame per cell: (K, B, ...) -> (B, K, ...)
            ebuf = buffer_add_many_batch(
                ebuf, jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), items))
            reqs = items["req"]                               # (K, B, U)
        elif stateful:
            (env,), (stats, reqs) = jax.lax.scan(slot_step, (env,),
                                                 (slot_keys, g_idx))
        else:
            (env,), stats = jax.lax.scan(slot_step, (env,),
                                         (slot_keys, g_idx))
        if stateful:
            cstate = jax.vmap(cacher.step_frame)(
                cstate, jnp.swapaxes(reqs, 0, 1), models, masks)
        storage_viol = (jnp.sum(rho * models.c, axis=-1)
                        > env_cfg.C).astype(jnp.float32)      # (B,)
        r_frame = jnp.mean(stats["r"], axis=0) - storage_viol * env_cfg.Xi
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": stats, "storage_viol": storage_viol}
        if tap_a:
            out["adiag"] = adiag               # ((K, ...) metrics, (K,) did)
        carry = ((alloc_state, ebuf, env) if alloc.learns else (env,))
        if stateful:
            carry = carry + (cstate,)
        return carry, out

    frame_xs = (jax.random.split(key, env_cfg.T), jnp.arange(env_cfg.T))
    init = ((ts["d3pg"], ts["ebuf"], env) if alloc.learns else (env,))
    if stateful:
        init = init + (ts["cache"],)
    final, frames = jax.lax.scan(frame_step, init, frame_xs)
    cache_state = final[-1] if stateful else ts["cache"]
    if stateful:
        final = final[:-1]
    if alloc.learns:
        alloc_state, ebuf, env = final
    else:
        (env,) = final
        alloc_state, ebuf = ts["d3pg"], ts["ebuf"]

    cacher_state, fbuf = ts["ddqn"], ts["fbuf"]
    cdiag = None
    if cacher.learns and train:
        def add_and_update(carry, t):
            cacher_state, fbuf = carry
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add_batch(fbuf, item)
            pred = jnp.sum(fbuf["size"]) > dq.batch

            def do_update(cs):
                kb = jax.random.fold_in(key, t)
                batch = pool(buffer_sample_batch(
                    fbuf, jax.random.split(kb, B), n_frame))
                cs, m = cacher.update(cs, batch, kb)
                return (cs, m) if tap_c else cs
            if tap_c:
                cacher_state, m = jax.lax.cond(
                    pred, do_update,
                    lambda cs: (cs, cacher.diag_zero()), cacher_state)
                return ((cacher_state, fbuf),
                        (m, pred.astype(jnp.float32)))
            cacher_state = jax.lax.cond(
                pred, do_update, lambda cs: cs, cacher_state)
            return (cacher_state, fbuf), None
        (cacher_state, fbuf), cdiag = jax.lax.scan(
            add_and_update, (cacher_state, fbuf),
            jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]                  # leaves (T, K, B)
    stats = {
        "episode_reward": jnp.sum(slot["r"], axis=(0, 1)),
        "mean_reward": jnp.mean(slot["r"], axis=(0, 1)),
        "hit_ratio": jnp.mean(slot["hit"], axis=(0, 1)),
        "utility": jnp.mean(slot["G"], axis=(0, 1)),
        "delay": jnp.mean(slot["delay"], axis=(0, 1)),
        "quality": jnp.mean(slot["quality"], axis=(0, 1)),
        "deadline_viol": jnp.mean(slot["viol"], axis=(0, 1)),
        "storage_viol": jnp.mean(frames["storage_viol"], axis=0),
    }
    if tap_a or tap_c:
        # the shared learner takes ONE pooled update per slot/frame, so its
        # diagnostics are cell-agnostic — broadcast to a leading (B,) so
        # the per-cell history layout stays uniform.
        diag = {}
        if tap_a:
            diag.update(reduce_update_diag(*frames["adiag"], prefix="diag/"))
        if tap_c:
            diag.update(reduce_update_diag(*cdiag, prefix="diag/ddqn_"))
        stats.update({k: jnp.broadcast_to(v, (B,) + v.shape)
                      for k, v in diag.items()})
    if train and cfg.obs.replay_on:
        # per-cell buffers: size/fill already carry the (B,) axis
        occ = {**buffer_occupancy(ebuf, "ebuf", capacity=d3.buffer),
               **buffer_occupancy(fbuf, "fbuf", capacity=dq.buffer)}
        stats.update({"diag/" + k: v for k, v in occ.items()})
    ts = {"models": models, "d3pg": alloc_state, "ddqn": cacher_state,
          "ebuf": ebuf, "fbuf": fbuf, "cache": cache_state}
    return ts, stats


def _episode_core_fused(ts, cfg: T2DRLCfg, keys, step, *,
                        train: bool = True, masks=None,
                        mods: Optional[ScenarioSchedule] = None):
    """One episode of B INDEPENDENT learners as a single fused batched
    program (DESIGN.md §13) — the scaling rewrite of
    ``jax.vmap(_episode_core)``.

    Every learner/buffer leaf carries a leading ``(B,)`` axis; the B
    per-cell network applies run as single batched contractions
    (``*_stacked`` paths), the B Adam steps as one fused pass, and the B
    replay gathers/scatters as one indexed op per leaf.  Per-cell PRNG
    derivations are replayed verbatim — every split/fold_in of the
    single-env core is vmapped over the per-cell keys.

    Equivalence contract vs ``jax.vmap(_episode_core)`` (pinned by
    ``tests/test_fused.py``): every stacked primitive/agent closure is
    bit-identical leaf for leaf, and all discrete decisions (caching
    actions, hit ratios, minibatch indices) stay exact at episode level;
    full episodes agree to float32 round-off only — slot-reward
    accumulations at the ULP level, trained parameters at ~1e-5 after
    one episode.  The residue is not a math difference — the minibatch
    indices, update inputs, and single update steps are bitwise equal —
    but XLA CPU codegen being context-dependent: two different
    whole-programs (including the vmap reference vs an isolated replay
    of its own update chain, measured at ~1e-10/update) fuse the reward
    sums and chained update arithmetic differently at ULP level, and
    training's discrete branches (eps-greedy, argmax, feasibility
    amenders) then amplify ULPs across episodes.

    The update gates use SCALAR predicates (``jnp.all`` over cells) inside
    real ``lax.cond``s: in independent mode every cell writes exactly K
    slot items per frame and T-1 frame items per episode in lockstep, so
    ptr/size are equal across cells and the per-cell predicates of the
    vmapped reference (which vmap degrades to compute-both-branches
    ``select``s) always agree — the scalar gate picks the same branch
    while actually skipping the update work pre-warmup.

    ``step`` values may be per-learner ``(B,)`` arrays (population
    training): ``eps``/``sigma``/``lr_actor``/``lr_critic`` as in the
    scalar case, plus ``lr_ddqn`` (cacher learning rate) and ``shape_hit``
    (a beyond-paper reward-shaping coefficient adding ``shape_hit *
    mean(hit)`` to the stored slot rewards and the frame reward — the
    reported stats stay unshaped).  Returns (ts, stats) with per-cell
    stats of shape (B,)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    alloc0, cacher0 = _agents(cfg)
    alloc = vmap_agent(alloc0, impl="fused")
    cacher = vmap_agent(cacher0, impl="fused")
    stateful = cacher0.step_frame is not None  # classical cacher (§14)
    # telemetry taps (DESIGN.md §15): python-static — off compiles the
    # exact pre-telemetry program.  The fused gates are scalar (jnp.all),
    # so one did flag covers all B learners; the zeros branch stacks the
    # single-learner diag_zero to (B,) to match the stacked update metrics
    tap_a = train and alloc0.diag_zero is not None
    tap_c = train and cacher0.diag_zero is not None
    models: ModelParams = ts["models"]
    cap_e = d3.buffer
    B = keys.shape[0]
    kk = jax.vmap(jax.random.split)(keys)                 # (B, 2, 2)
    k_env, keyd = kk[:, 0], kk[:, 1]    # per-cell env-reset / driver keys
    env = env_reset_batch(k_env, env_cfg, schedule_slot_mod(mods, 0))
    shape_hit = step.get("shape_hit")

    def observe_b(env):
        return jax.vmap(lambda e, m, mk: observe(e, env_cfg, m, mk))(
            env, models, masks)                           # (B, S)

    def slot_stats(r, m):
        return {"r": r, "hit": _batch_mean(m["cached"], masks),
                "G": _batch_mean(m["G"], masks),
                "delay": _batch_mean(m["d_tl"], masks),
                "quality": _batch_mean(m["quality"], masks),
                "viol": _batch_mean(
                    (m["d_tl"] > env_cfg.tau).astype(jnp.float32), masks)}

    def frame_step(carry, xs):
        k_frame, t = xs               # k_frame: (B, 2); t: frame index
        if stateful:
            carry, cstate = carry[:-1], carry[-1]
        if alloc0.learns:
            alloc_state, ebuf, env = carry
        else:
            alloc_state, (env,) = ts["d3pg"], carry
        kf = jax.vmap(lambda k: jax.random.split(k, 3))(k_frame)  # (B, 3, 2)
        env = jax.vmap(lambda e, P, md: env_advance_frame(e, env_cfg, P, md))(
            env, schedule_frame_P(mods, t),
            schedule_slot_mod(mods, t * env_cfg.K))
        gamma_t = env.gamma_idx                           # (B,)
        a_int, rho = cacher.act(cstate if stateful else ts["ddqn"],
                                FrameObs(gamma_t, models), kf[:, 0], step)
        env = jax.vmap(env_set_cache)(env, rho)
        size0 = ebuf["size"] if alloc0.learns else None   # (B,) lockstep

        def slot_step(carry, xs):
            k_slot, g = xs             # k_slot: (B, 2); g: global slot index
            if alloc0.learns:
                alloc_state, env, s = carry
            else:
                alloc_state, (env,), s = ts["d3pg"], carry, None
            ks = jax.vmap(lambda k: jax.random.split(k, 4))(k_slot)
            b, xi = alloc.act(alloc_state, SlotObs(s, env, models, masks),
                              ks[:, :2], step)
            env1, r, m = jax.vmap(
                lambda e, mo, bb, xx, mk, md: env_step_slot(
                    e, env_cfg, mo, bb, xx, mk, md))(
                env, models, b, xi, masks, schedule_slot_mod(mods, g + 1))
            st = slot_stats(r, m)
            if not alloc0.learns:
                return (env1,), ((st, env.req) if stateful else st)
            s1 = observe_b(env1)
            r_store = r if shape_hit is None else r + shape_hit * st["hit"]
            item = {"s": s, "a": jnp.concatenate([b, xi], axis=-1),
                    "r": r_store, "s1": s1, "req": env.req, "rho": env.rho,
                    "req1": env1.req, "rho1": env1.rho}
            if train:
                # transitions stored so far = frame-start size + slot count
                # (writes are batched at frame end); lockstep across cells,
                # so the scalar all() gate agrees with every per-cell
                # predicate of the vmapped reference
                k_in = g - t * env_cfg.K
                stored = jnp.minimum(size0 + k_in + 1, cap_e)
                pred = jnp.all((stored > cfg.warmup) & (size0 > 0))
                sample = lambda k: buffer_sample_stacked(ebuf, k, d3.batch)
                if tap_a:
                    alloc_state, adiag = jax.lax.cond(
                        pred,
                        lambda st_: _slot_updates_stacked(
                            alloc, cfg, st_, ks, step, masks, sample,
                            tap=True),
                        lambda st_: (st_, broadcast_diag(
                            alloc0.diag_zero(), B)), alloc_state)
                    return ((alloc_state, env1, s1),
                            (st, item, (adiag, pred.astype(jnp.float32))))
                alloc_state = jax.lax.cond(
                    pred,
                    lambda st_: _slot_updates_stacked(
                        alloc, cfg, st_, ks, step, masks, sample),
                    lambda st_: st_, alloc_state)
            return (alloc_state, env1, s1), (st, item)

        g_idx = t * env_cfg.K + jnp.arange(env_cfg.K)
        slot_keys = jnp.moveaxis(
            jax.vmap(lambda k: jax.random.split(k, env_cfg.K))(kf[:, 1]),
            1, 0)                                         # (K, B, 2)
        reqs = adiag = None
        if alloc0.learns:
            s = observe_b(env)
            if tap_a:
                (alloc_state, env, _), (stats, items, adiag) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            else:
                (alloc_state, env, _), (stats, items) = jax.lax.scan(
                    slot_step, (alloc_state, env, s), (slot_keys, g_idx))
            # one fused write per frame: (K, B, ...) -> (B, K, ...)
            ebuf = buffer_add_many_stacked(
                ebuf, jax.tree.map(lambda x: jnp.swapaxes(x, 0, 1), items))
            reqs = items["req"]                           # (K, B, U)
        elif stateful:
            (env,), (stats, reqs) = jax.lax.scan(slot_step, (env,),
                                                 (slot_keys, g_idx))
        else:
            (env,), stats = jax.lax.scan(slot_step, (env,),
                                         (slot_keys, g_idx))
        if stateful:
            cstate = jax.vmap(cacher0.step_frame)(
                cstate, jnp.swapaxes(reqs, 0, 1), models, masks)
        storage_viol = (jnp.sum(rho * models.c, axis=-1)
                        > env_cfg.C).astype(jnp.float32)  # (B,)
        r_frame = jnp.mean(stats["r"], axis=0) - storage_viol * env_cfg.Xi
        if shape_hit is not None:
            r_frame = r_frame + shape_hit * jnp.mean(stats["hit"], axis=0)
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": stats, "storage_viol": storage_viol}
        if tap_a:
            out["adiag"] = adiag           # ((K, B, ...) metrics, (K,) did)
        carry = ((alloc_state, ebuf, env) if alloc0.learns else (env,))
        if stateful:
            carry = carry + (cstate,)
        return carry, out

    frame_keys = jnp.moveaxis(
        jax.vmap(lambda k: jax.random.split(k, env_cfg.T))(keyd), 1, 0)
    frame_xs = (frame_keys, jnp.arange(env_cfg.T))
    init = ((ts["d3pg"], ts["ebuf"], env) if alloc0.learns else (env,))
    if stateful:
        init = init + (ts["cache"],)
    final, frames = jax.lax.scan(frame_step, init, frame_xs)
    cache_state = final[-1] if stateful else ts["cache"]
    if stateful:
        final = final[:-1]
    if alloc0.learns:
        alloc_state, ebuf, env = final
    else:
        (env,) = final
        alloc_state, ebuf = ts["d3pg"], ts["ebuf"]

    cacher_state, fbuf = ts["ddqn"], ts["fbuf"]
    cdiag = None
    if cacher0.learns and train:
        def add_and_update(carry, t):
            cacher_state, fbuf = carry
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add_batch(fbuf, item)
            pred = jnp.all(fbuf["size"] > dq.batch)

            def do_update(cs):
                kb = jax.vmap(lambda k: jax.random.fold_in(k, t))(keyd)
                batch = buffer_sample_stacked(fbuf, kb, dq.batch)
                if "lr_ddqn" in step:
                    batch = {**batch, "lr": step["lr_ddqn"]}
                cs, m = cacher.update(cs, batch, kb)
                return (cs, m) if tap_c else cs
            if tap_c:
                cacher_state, m = jax.lax.cond(
                    pred, do_update,
                    lambda cs: (cs, broadcast_diag(cacher0.diag_zero(), B)),
                    cacher_state)
                return ((cacher_state, fbuf),
                        (m, pred.astype(jnp.float32)))
            cacher_state = jax.lax.cond(pred, do_update,
                                        lambda cs: cs, cacher_state)
            return (cacher_state, fbuf), None
        (cacher_state, fbuf), cdiag = jax.lax.scan(
            add_and_update, (cacher_state, fbuf),
            jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]                  # leaves (T, K, B)
    stats = {
        "episode_reward": jnp.sum(slot["r"], axis=(0, 1)),
        "mean_reward": jnp.mean(slot["r"], axis=(0, 1)),
        "hit_ratio": jnp.mean(slot["hit"], axis=(0, 1)),
        "utility": jnp.mean(slot["G"], axis=(0, 1)),
        "delay": jnp.mean(slot["delay"], axis=(0, 1)),
        "quality": jnp.mean(slot["quality"], axis=(0, 1)),
        "deadline_viol": jnp.mean(slot["viol"], axis=(0, 1)),
        "storage_viol": jnp.mean(frames["storage_viol"], axis=0),
    }
    if tap_a or tap_c:
        # per-learner metric leaves reduce to (B,) / (B, L); the shared
        # scalar `updates` counts are broadcast so every diag leaf leads
        # with the cell axis
        diag = {}
        if tap_a:
            diag.update(reduce_update_diag(*frames["adiag"], prefix="diag/"))
        if tap_c:
            diag.update(reduce_update_diag(*cdiag, prefix="diag/ddqn_"))
        stats.update({k: (jnp.broadcast_to(v, (B,)) if v.ndim == 0 else v)
                      for k, v in diag.items()})
    if train and cfg.obs.replay_on:
        # stacked buffers: size is already per-cell (B,)
        occ = {**buffer_occupancy(ebuf, "ebuf", capacity=d3.buffer),
               **buffer_occupancy(fbuf, "fbuf", capacity=dq.buffer)}
        stats.update({"diag/" + k: v for k, v in occ.items()})
    ts = {"models": models, "d3pg": alloc_state, "ddqn": cacher_state,
          "ebuf": ebuf, "fbuf": fbuf, "cache": cache_state}
    return ts, stats


def _episode_batch(ts, cfg: T2DRLCfg, keys, step, *, train: bool,
                   masks=None, mods=None):
    """One episode across the batch; keys: (B,) per-cell episode keys.

    ``cfg.policy == "independent"`` runs B independent learners — as ONE
    fused batched program (``independent_impl="fused"``, the default) or
    as the legacy vmap of the single-env episode (``"vmap"``, the
    bit-identity reference).  B=1 bypasses both so the single-env program
    (and its cond-based update gating) is preserved exactly — unless the
    ``step`` dict carries per-cell ``(B,)`` schedule values (population
    training), which only the fused core understands.  ``"shared"``
    delegates to the shared-learner lockstep core.  ``mods``: optional
    ScenarioSchedule with per-cell (B,)-leading leaves."""
    if cfg.policy == "shared":
        return _episode_core_shared(ts, cfg, keys, step, train=train,
                                    masks=masks, mods=mods)
    if cfg.independent_impl not in ("fused", "vmap"):
        raise ValueError(
            f"unknown independent_impl {cfg.independent_impl!r}; "
            "expected 'fused' or 'vmap'")
    B = keys.shape[0]
    pop_step = any(jnp.ndim(v) for v in step.values())
    if pop_step and cfg.independent_impl != "fused":
        raise ValueError("per-cell (population) schedules require "
                         "independent_impl='fused'")
    if cfg.independent_impl == "fused" and (B > 1 or pop_step):
        return _episode_core_fused(ts, cfg, keys, step, train=train,
                                   masks=masks, mods=mods)
    if B == 1:
        mask = None if masks is None else masks[0]
        mods1 = None if mods is None else jax.tree.map(lambda x: x[0], mods)
        ts1, stats = _episode_core(
            jax.tree.map(lambda x: x[0], ts), cfg, keys[0], step,
            train=train, mask=mask, mods=mods1)
        expand = functools.partial(jax.tree.map, lambda x: x[None])
        return expand(ts1), expand(stats)
    return jax.vmap(
        lambda t, k, m, md: _episode_core(t, cfg, k, step, train=train,
                                          mask=m, mods=md))(
        ts, keys, masks, mods)


# -- compiled entry points ----------------------------------------------------
#
# On CPU the mostly-sequential episode programs — the single-env scan and
# the shared-learner lockstep scan — execute measurably faster (~1.15x on
# the 2-core CI box) under XLA's sequential (non-thunk) runtime, so those
# entry points are AOT-compiled with that option and cached per (config,
# train flag, argument structure).  The vmapped independent-learner program
# (B > 1) is the opposite case — its B stacked per-cell updates benefit
# from the thunk runtime's scheduling (~2.5x over sequential, measured) —
# so it keeps the default compile.  run_episode and run_training share the
# machinery, keeping the B=1 equivalence pin exact; unknown options
# (future jaxlib) fall back to the default compile, and non-CPU backends
# use the plain jit path untouched.

_CPU_EPISODE_COMPILER_OPTIONS = {"xla_cpu_use_thunk_runtime": False}
_AOT_CACHE: dict = {}


def _episode_compiler_options(cfg: T2DRLCfg, num_envs: int):
    """Compiler options for an episode program: sequential runtime for the
    single-env, shared-learner, and fused independent-learner scans —
    all are one mostly-sequential batched program — default (thunk) only
    for the legacy vmapped independent path, whose B interleaved
    per-cell programs benefit from thunk scheduling (see block comment
    above; DESIGN.md §13)."""
    if cfg.policy == "shared" or num_envs == 1:
        return _CPU_EPISODE_COMPILER_OPTIONS
    if cfg.policy == "independent" and cfg.independent_impl == "fused":
        return _CPU_EPISODE_COMPILER_OPTIONS
    return None


def _args_signature(tree):
    try:
        from jax.api_util import shaped_abstractify
        leaves, treedef = jax.tree.flatten(tree)
        return (treedef,) + tuple(shaped_abstractify(l) for l in leaves)
    except Exception:
        leaves, treedef = jax.tree.flatten(tree)
        return (treedef,) + tuple(
            (jnp.shape(l), jnp.result_type(l)) for l in leaves)


def _aot_episode_call(tag, jitted, static_kw, dyn_args, options):
    """Call ``jitted`` through the AOT cache with the given CPU compiler
    options; fall back to the plain jit path off-CPU, for ``options=None``,
    or if the options are rejected (future jaxlib).

    Every compile — AOT cache miss or plain-jit cache growth — is reported
    to the ``repro.obs.profiling`` recompile counter (DESIGN.md §15).  The
    counter tag is namespaced per static config so distinct experiment
    configs don't read as retraces of one another; within one config the
    expected program count is two (full chunk + ragged remainder), and the
    counter warns beyond that."""
    statics = tuple(sorted(static_kw.items()))
    full_tag = f"{tag}:{hash(statics) & 0xFFFFFFFF:08x}"
    if options is None or jax.default_backend() != "cpu":
        before = jitted._cache_size()
        out = jitted(*dyn_args, **static_kw)
        if jitted._cache_size() > before:
            record_compile(full_tag, repr(_args_signature(dyn_args)))
        return out
    sig = (tag,) + statics + _args_signature(dyn_args)
    compiled = _AOT_CACHE.get(sig)
    if compiled is None:
        record_compile(full_tag, repr(_args_signature(dyn_args)))
        lowered = jitted.lower(*dyn_args, **static_kw)
        try:
            compiled = lowered.compile(compiler_options=options)
        except Exception:
            compiled = lowered.compile()
        _AOT_CACHE[sig] = compiled
    return compiled(*dyn_args)


def _run_episode_impl(ts, key, eps, sigma, mods=None, *, cfg: T2DRLCfg,
                      train: bool = True):
    return _episode_core(ts, cfg, key, {"eps": eps, "sigma": sigma},
                         train=train, mods=mods)


_run_episode_jit = functools.partial(
    jax.jit, static_argnames=("cfg", "train"))(_run_episode_impl)


def run_episode(ts, cfg: T2DRLCfg, key, eps, sigma, *, train: bool = True,
                mods: Optional[ScenarioSchedule] = None):
    """One episode of Algorithm 1 (single env).  ``mods``: optional
    unbatched ScenarioSchedule (DESIGN.md §9).  Returns (ts, stats)."""
    return _aot_episode_call("episode", _run_episode_jit,
                             {"cfg": cfg, "train": train},
                             (ts, key, eps, sigma, mods),
                             _episode_compiler_options(cfg, 1))


def _training_xs(cfg: T2DRLCfg, key, ep_idx, B: int, *, train: bool,
                 pop=None):
    """Precomputed per-episode scan inputs: per-cell episode keys
    ``(E, B, 2)`` plus the eps/sigma (and any LR-warmdown) schedule arrays.
    ``pop`` entries (validated ``(E, B)`` arrays, see ``run_training``)
    override/extend the scalar schedules with per-member ones."""
    alloc, _ = _agents(cfg)
    e = ep_idx.astype(jnp.float32)
    xs = {"keys": jax.vmap(
              lambda ep: _batch_keys(jax.random.fold_in(key, ep), B))(ep_idx),
          "eps": episode_epsilon(cfg, e),
          "sigma": episode_sigma(cfg, e)}
    if train and alloc.learns and cfg.lr_schedule != "const":
        scale = episode_lr_scale(cfg, e)
        xs["lr_actor"] = cfg.lr_actor * scale
        xs["lr_critic"] = cfg.lr_critic * scale
    if pop:
        xs.update(pop)
    return xs


def _scan_episodes(ts, cfg: T2DRLCfg, xs, *, train: bool, masks=None,
                   mods=None):
    """Scan the batched episode over precomputed per-episode inputs."""
    def ep_step(ts, x):
        step = {k: v for k, v in x.items() if k != "keys"}
        return _episode_batch(ts, cfg, x["keys"], step, train=train,
                              masks=masks, mods=mods)

    return jax.lax.scan(ep_step, ts, xs)


def _run_training_impl(ts, key, ep_idx, masks=None, mods=None, pop=None, *,
                       cfg: T2DRLCfg, train: bool = True):
    B = ts["models"].a1.shape[0]
    xs = _training_xs(cfg, key, ep_idx, B, train=train, pop=pop)
    return _scan_episodes(ts, cfg, xs, train=train, masks=masks, mods=mods)


_run_training_jit = functools.partial(
    jax.jit, static_argnames=("cfg", "train"),
    donate_argnums=(0,))(_run_training_impl)


_POP_KEYS = ("eps", "sigma", "lr_actor", "lr_critic", "lr_ddqn", "shape_hit")


def _validate_pop(pop, cfg: T2DRLCfg, B: int, E: int):
    """Normalize a population-schedule dict to ``(E, B)`` float arrays.

    Allowed keys (DESIGN.md §13): ``eps``, ``sigma``, ``lr_actor``,
    ``lr_critic``, ``lr_ddqn``, ``shape_hit``.  Entries may be ``(B,)``
    (constant per member) or ``(E, B)`` (full per-member schedules).
    Population schedules exist only on the fused independent path."""
    if pop is None:
        return None
    unknown = set(pop) - set(_POP_KEYS)
    if unknown:
        raise ValueError(f"unknown population keys {sorted(unknown)}; "
                         f"expected a subset of {_POP_KEYS}")
    if cfg.policy != "independent" or cfg.independent_impl != "fused":
        raise ValueError(
            "population schedules require policy='independent' and "
            "independent_impl='fused' (DESIGN.md §13)")
    out = {}
    for k, v in pop.items():
        v = jnp.asarray(v, jnp.float32)
        if v.ndim == 1:
            v = jnp.broadcast_to(v[None], (E,) + v.shape)
        if v.shape != (E, B):
            raise ValueError(f"population key {k!r} must be (B,)=({B},) or "
                             f"(E, B)=({E}, {B}); got {v.shape}")
        out[k] = v
    # Agent.update consumes lr_actor/lr_critic as a pair — fill a missing
    # partner with the configured constant so the aux dict stays complete
    if ("lr_actor" in out) != ("lr_critic" in out):
        k_have = "lr_actor" if "lr_actor" in out else "lr_critic"
        k_miss = "lr_critic" if k_have == "lr_actor" else "lr_actor"
        const = cfg.lr_critic if k_miss == "lr_critic" else cfg.lr_actor
        out[k_miss] = jnp.full((E, B), const, jnp.float32)
    return out


def run_training(ts, cfg: T2DRLCfg, key, ep_idx, masks=None, mods=None, *,
                 train: bool = True, pop=None):
    """Scan the batched episode over the (absolute) episode indices
    ``ep_idx`` — a whole multi-episode, multi-cell run in one compiled call.
    Epsilon/sigma (and any LR-warmdown) schedules are precomputed arrays
    fed to the scan as inputs.  ``mods``: optional ScenarioSchedule with
    per-cell (B,)-leading leaves, replayed every episode.

    ``pop``: optional population-schedule dict (DESIGN.md §13) giving each
    of the B cells its OWN hyperparameters — keys among ``eps``, ``sigma``,
    ``lr_actor``, ``lr_critic``, ``lr_ddqn``, ``shape_hit``; values
    ``(B,)`` or ``(E, B)`` arrays.  One compiled call then trains B
    population members that differ in those knobs (fused independent
    mode only).

    ``ts`` is DONATED to the computation (its buffers are reused in place);
    use the returned state and do not touch the argument afterwards.
    Returns (ts, history) with history leaves of shape (len(ep_idx), B)."""
    B = ts["models"].a1.shape[0]
    pop = _validate_pop(pop, cfg, B, len(ep_idx))
    return _aot_episode_call("train", _run_training_jit,
                             {"cfg": cfg, "train": train},
                             (ts, key, ep_idx, masks, mods, pop),
                             _episode_compiler_options(cfg, B))


def run_training_sharded(ts, cfg: T2DRLCfg, key, ep_idx, masks=None, *,
                         train: bool = True, pop=None, mesh=None):
    """``run_training`` with the B independent cells sharded across devices
    via ``jax.experimental.shard_map`` (opt-in, DESIGN.md §13).

    Each device runs the fused episode program on its contiguous slice of
    cells; there is no cross-cell communication (independent learners), so
    the result equals the single-device ``run_training`` — per-cell episode
    keys are derived from GLOBAL cell indices *before* sharding, and each
    shard replays exactly its cells' PRNG streams
    (``tests/test_fused.py`` pins the equivalence under a forced host
    device count).

    ``mesh`` defaults to a 1-D ``("cells",)`` mesh over every visible
    device (``repro.launch.mesh.make_cells_mesh``); on CPU, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before first
    jax use to expose N devices.  B must divide evenly across the mesh.
    ``mods`` schedules are not supported on this path; ``ts`` is not
    donated."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    if cfg.policy != "independent" or cfg.independent_impl != "fused":
        raise ValueError("run_training_sharded requires policy="
                         "'independent' and independent_impl='fused'")
    B = ts["models"].a1.shape[0]
    if mesh is None:
        from repro.launch.mesh import make_cells_mesh
        mesh = make_cells_mesh()
    n = int(mesh.devices.size)
    if B % n:
        raise ValueError(f"num_envs={B} must be divisible by the mesh's "
                         f"{n} devices")
    pop = _validate_pop(pop, cfg, B, len(ep_idx))
    xs = _training_xs(cfg, key, ep_idx, B, train=train, pop=pop)
    xs_specs = {k: (P(None, "cells") if jnp.ndim(v) > 1 else P(None))
                for k, v in xs.items()}

    def local(ts_, xs_, masks_):
        return _scan_episodes(ts_, cfg, xs_, train=train, masks=masks_)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P("cells"), xs_specs, P("cells")),
                   out_specs=(P("cells"), P(None, "cells")))
    return jax.jit(fn)(ts, xs, masks)


@functools.partial(jax.jit, static_argnames=("cfg",))
def run_eval(ts, cfg: T2DRLCfg, key, ep_idx, masks=None, mods=None):
    """Greedy evaluation scan: eps = sigma = 0, no updates, ``ts`` is not
    threaded between episodes (and, unlike ``run_training``, not donated).
    Returns history leaves (len(ep_idx), B)."""
    B = ts["models"].a1.shape[0]
    zero = jnp.float32(0.0)
    step = {"eps": zero, "sigma": zero}

    def ep_step(_, ep):
        k_ep = jax.random.fold_in(key, ep)
        _, stats = _episode_batch(ts, cfg, _batch_keys(k_ep, B), step,
                                  train=False, masks=masks, mods=mods)
        return None, stats

    _, stats = jax.lax.scan(ep_step, None, ep_idx)
    return stats


_ENV_AXIS_KEYS = ("models", "ebuf", "fbuf", "cache")  # always batched in
#                         batch mode (cache state is per-cell even when the
#                         learner parameters are shared, DESIGN.md §14)


def _squeeze_env_axis(ts, cfg: T2DRLCfg):
    """Drop the leading B=1 axis, giving a legacy-shaped train state.  In
    shared-policy mode the agent parameters never had an env axis."""
    keys = (_ENV_AXIS_KEYS if cfg.policy == "shared" else ts.keys())
    return {k: (jax.tree.map(lambda x: x[0], v) if k in keys else v)
            for k, v in ts.items()}


def _expand_env_axis(ts, cfg: T2DRLCfg):
    keys = (_ENV_AXIS_KEYS if cfg.policy == "shared" else ts.keys())
    return {k: (jax.tree.map(lambda x: x[None], v) if k in keys else v)
            for k, v in ts.items()}


def _broadcast_mods(mods: Optional[ScenarioSchedule], num_envs: int):
    """Give an unbatched schedule a leading (num_envs,) cell axis (no-op for
    already-batched schedules or ``None``)."""
    if mods is None:
        return None
    if mods.h_scale.ndim == 2:
        if mods.h_scale.shape[0] != num_envs:
            raise ValueError(
                f"per-cell schedule was built for {mods.h_scale.shape[0]} "
                f"cells but num_envs={num_envs}; rebuild with "
                f"build_scenario(..., num_envs={num_envs})")
        return mods
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_envs,) + x.shape), mods)


def _chunk_summary(stats):
    """Host-side summary of one logical chunk's history for the telemetry
    record: per-key means as python floats, except per-step diffusion
    magnitudes (``*denoise_mag``) which keep their trailing chain axis as
    an L-vector (mean over episodes/cells only)."""
    out = {}
    for k, v in stats.items():
        if k.endswith("denoise_mag") and v.ndim >= 2:
            out[k] = [float(x) for x in
                      jnp.mean(v.reshape(-1, v.shape[-1]), axis=0)]
        else:
            out[k] = float(jnp.mean(v))
    return out


def train_t2drl(cfg: T2DRLCfg, *, episodes: Optional[int] = None,
                num_envs: int = 1, user_counts: Optional[Sequence[int]] = None,
                share_models: bool = False, log_every: int = 0,
                callback=None, mods: Optional[ScenarioSchedule] = None,
                writer=None):
    """Full training run over ``num_envs`` parallel edge cells (multi-seed).

    Parameters
    ----------
    cfg : T2DRLCfg
        Method + environment configuration (jit-static).
    episodes : int, optional
        Episode count (defaults to ``cfg.episodes``).
    num_envs : int
        Number of parallel edge cells B trained through the vectorized core
        (DESIGN.md §6).  ``cfg.policy`` selects independent vs shared
        learners.
    user_counts : sequence of int, optional
        Per-cell active-user counts (len ``num_envs``) — heterogeneous
        populations via masking.
    share_models : bool
        Broadcast cell 0's model zoo to every cell (pure multi-seed runs).
    log_every : int
        Print a progress line every N episodes (chunks the episode scan;
        results are unchanged because keys derive from absolute indices).
    callback : callable, optional
        ``callback(episode, mean_stats)`` after every episode.
    mods : ScenarioSchedule, optional
        Scenario modulation schedule (DESIGN.md §9), e.g. from
        ``repro.scenarios.build_scenario``.  Unbatched leaves are broadcast
        to all cells; per-cell leaves (leading ``(num_envs,)`` axis) give
        heterogeneous scenarios.
    writer : repro.obs.MetricWriter, optional
        Structured telemetry sink (DESIGN.md §15).  When given, a run
        manifest is stamped once and a ``train_chunk`` record (episode
        cursor, wall-clock, per-key chunk statistics) is emitted after
        every logical chunk.  Purely host-side — the compiled programs
        and results are identical with or without a writer.

    Returns
    -------
    (dict, dict)
        Final train-state pytree and history dict of stacked arrays.
        History leaves have shape ``(episodes,)`` for ``num_envs=1``
        (legacy layout) and ``(episodes, num_envs)`` otherwise; likewise
        the train state keeps its leading batch axis only for
        ``num_envs > 1``.
    """
    episodes = episodes or cfg.episodes
    key = jax.random.PRNGKey(cfg.seed)
    k_init, key = jax.random.split(key)
    ts = t2drl_init_batch(k_init, cfg, num_envs, share_models=share_models)
    masks = None
    if user_counts is not None:
        if len(user_counts) != num_envs:
            raise ValueError("user_counts must have one entry per env")
        masks = make_user_masks(cfg.env, user_counts)
    mods = _broadcast_mods(mods, num_envs)
    if writer is not None:
        writer.ensure_manifest(cfg, extra={"episodes": int(episodes),
                                           "num_envs": int(num_envs)})
    chunk = episodes if not (log_every or callback) else (log_every or 1)
    chunks, ep0 = [], 0
    while ep0 < episodes:
        n = min(chunk, episodes - ep0)
        # ragged-tail fix (DESIGN.md §15): a final chunk of n < chunk used
        # to trace a THIRD program per config (silent retrace).  Run the
        # remainder as size-1 calls instead, so a chunked run compiles
        # exactly two episode programs: chunk-sized and size-1.  Episode
        # keys derive from absolute indices, so the split leaves results
        # bit-identical.
        sizes = [n] if n == chunk else [1] * n
        t0 = time.perf_counter()
        parts, e = [], ep0
        for m in sizes:
            ts, part = run_training(ts, cfg, key, jnp.arange(e, e + m),
                                    masks, mods, train=True)
            parts.append(part)
            e += m
        stats = (parts[0] if len(parts) == 1 else
                 {k: jnp.concatenate([p[k] for p in parts])
                  for k in parts[0]})
        chunks.append(stats)
        if writer is not None:
            jax.block_until_ready(stats)
            writer.write("train_chunk", episode=ep0 + n,
                         episodes=int(episodes),
                         wall_s=time.perf_counter() - t0,
                         stats=_chunk_summary(stats))
        if log_every:
            last = {k: float(jnp.mean(v[-1])) for k, v in stats.items()}
            print(progress_line(ep0 + n, last))
        if callback is not None:
            for i in range(n):
                callback(ep0 + i,
                         jax.tree.map(lambda x: jnp.mean(x[i]), stats))
        ep0 += n
    history = {k: jnp.concatenate([c[k] for c in chunks])
               for k in chunks[0]}
    if num_envs == 1:
        ts = _squeeze_env_axis(ts, cfg)
        history = {k: v[:, 0] for k, v in history.items()}
    return ts, history


def eval_t2drl(ts, cfg: T2DRLCfg, *, episodes: int = 10, seed: int = 10_000,
               user_counts: Optional[Sequence[int]] = None,
               mods: Optional[ScenarioSchedule] = None):
    """Greedy evaluation (no exploration, no updates).

    Parameters
    ----------
    ts : dict
        Train-state pytree — single (legacy layout) or batched (leading
        ``(B,)`` axis, as returned by ``train_t2drl(..., num_envs=B)``).
    cfg : T2DRLCfg
        Method + environment configuration (jit-static).
    episodes : int
        Number of greedy evaluation episodes.
    seed : int
        PRNG seed for the evaluation episode keys (disjoint from training
        seeds by default).
    user_counts : sequence of int, optional
        Per-cell active-user counts (one entry per cell in ``ts``).
    mods : ScenarioSchedule, optional
        Scenario modulation schedule; unbatched leaves are broadcast to all
        cells.  Evaluating under a different schedule than training
        measures out-of-scenario generalization.

    Returns
    -------
    dict
        Scalar means over episodes and cells: ``episode_reward``,
        ``mean_reward``, ``hit_ratio``, ``utility``, ``delay``,
        ``quality``, ``deadline_viol``, ``storage_viol``.
    """
    batched = ts["models"].a1.ndim == 2
    if not batched:
        ts = _expand_env_axis(ts, cfg)
    B = ts["models"].a1.shape[0]
    masks = None
    if user_counts is not None:
        if len(user_counts) != B:
            raise ValueError("user_counts must have one entry per env")
        masks = make_user_masks(cfg.env, user_counts)
    stats = run_eval(ts, cfg, jax.random.PRNGKey(seed),
                     jnp.arange(episodes), masks, _broadcast_mods(mods, B))
    return {k: jnp.mean(v) for k, v in stats.items()}


# -- policy deployment (inference-only, DESIGN.md §11/§12) --------------------
#
# ``export_policy`` asks each Agent for its inference-only parameter slice
# (``Agent.export``), so checkpointing (repro.checkpoint.save_train_state)
# and the request-level fleet twin (repro.fleet) never branch on agent
# kinds.  ``greedy_slot_action`` / ``greedy_frame_cache`` are the greedy
# inference entry points every allocator/cacher combination shares,
# delegating to ``Agent.greedy``.


def export_policy(ts, cfg: T2DRLCfg, cell: int = 0):
    """Extract the inference-only policy pytree from a train state.

    Parameters
    ----------
    ts : dict
        Train state — legacy single-env layout or batched (leading ``(B,)``
        axis) as returned by ``train_t2drl(..., num_envs=B)``.
    cfg : T2DRLCfg
        The configuration the state was trained under (selects which agent
        parameters exist).
    cell : int
        For batched *independent*-policy states, which cell's learner to
        export.  Shared-policy states have a single learner; ``cell`` is
        then ignored and the shared parameters are taken as-is.

    Returns
    -------
    dict
        ``{"actor": ..., "ddqn": {"q": ...}}`` with keys present only for
        the learned components of ``cfg`` (empty dict for RCARS/SCHRS);
        classical cachers (DESIGN.md §14) export ``{"cache": {"rho":
        ...}}`` — the frozen resident set the twin serves greedily.
        Model zoos are *not* included — they are environment state, passed
        to the twin separately.
    """
    alloc, cacher = _agents(cfg)
    batched_agents = (ts["models"].a1.ndim == 2 and cfg.policy != "shared")
    take = ((lambda x: jax.tree.map(lambda v: v[cell], x))
            if batched_agents else (lambda x: x))
    pol = {}
    if alloc.learns:
        pol.update(alloc.export(take(ts["d3pg"])))
    if cacher.learns:
        pol.update(cacher.export(take(ts["ddqn"])))
    elif cacher.step_frame is not None:
        # cache state is per-cell even in shared mode (_ENV_AXIS_KEYS),
        # so slice on the models axis, not the agent axis
        take_cell = ((lambda x: jax.tree.map(lambda v: v[cell], x))
                     if ts["models"].a1.ndim == 2 else (lambda x: x))
        pol.update(cacher.export(take_cell(ts["cache"])))
    return pol


def greedy_slot_action(policy, cfg: T2DRLCfg, env: EnvState,
                       models: ModelParams, key, mask=None):
    """Greedy (no exploration noise) per-slot allocation for any allocator.

    Returns the amended ``(b, xi)`` exactly as the training-time slot step
    would under ``sigma = 0``; ``key`` drives the diffusion actor's reverse
    chain (D3PG) or the GA (SCHRS)."""
    alloc, _ = _agents(cfg)
    s = observe(env, cfg.env, models, mask) if alloc.learns else None
    return alloc.greedy(policy, SlotObs(s, env, models, mask), key)


def greedy_frame_cache(policy, cfg: T2DRLCfg, models: ModelParams,
                       gamma_idx, key):
    """Greedy (eps = 0) per-frame caching vector rho for any cacher."""
    _, cacher = _agents(cfg)
    return cacher.greedy(policy, FrameObs(gamma_idx, models), key)
