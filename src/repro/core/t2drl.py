"""T2DRL — the paper's Algorithm 1: outer long-timescale DDQN (caching) +
inner short-timescale D3PG (resource allocation), fully jitted per episode.

``allocator``/``cacher`` select the agent combination, covering the paper's
benchmarks:

  T2DRL             allocator="d3pg",  cacher="ddqn"
  DDPG-based T2DRL  allocator="ddpg",  cacher="ddqn"
  SCHRS             allocator="schrs", cacher="static"
  RCARS             allocator="rcars", cacher="random"

Vectorized training core (DESIGN.md §6): the per-episode logic lives in
``_episode_core`` (single env, optionally user-masked).  ``run_training``
vmaps it over a leading batch axis of B independent edge cells — each with
its own model zoo, replay buffers, agent parameters, and popularity /
location Markov chains — and scans over episodes, so an entire multi-seed,
multi-episode run is ONE compiled call.  ``run_episode`` remains the public
single-env entry point, and B=1 bypasses vmap entirely, so the legacy path
is reproduced exactly (cell 0 of any batch uses the same keys as a legacy
single-env run with the same seed).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .baselines import (GACfg, ga_allocate, random_cache, random_cache_batch,
                        rcars_allocate, static_popular_cache,
                        static_popular_cache_batch)
from .buffers import (buffer_add, buffer_add_batch, buffer_init,
                      buffer_sample, buffer_sample_batch)
from .d3pg import (D3PGCfg, actor_act, amend_actions, d3pg_init, d3pg_update,
                   make_actor_schedule)
from .ddqn import DDQNCfg, amend_caching, ddqn_act, ddqn_init, ddqn_update
from .env import (EnvCfg, EnvState, ModelParams, ScenarioSchedule,
                  env_advance_frame, env_reset, env_reset_batch,
                  env_set_cache, env_step_slot, make_models, make_user_masks,
                  masked_mean, observe, schedule_frame_P, schedule_slot_mod)


@dataclasses.dataclass(frozen=True)
class T2DRLCfg:
    """Static configuration of the two-timescale driver (jit-static).

    Attributes
    ----------
    env : EnvCfg
        Environment configuration (scenario transforms replace this).
    allocator : {"d3pg", "ddpg", "schrs", "rcars"}
        Short-timescale per-slot resource allocator.
    cacher : {"ddqn", "static", "random"}
        Long-timescale per-frame caching agent.
    policy : {"independent", "shared"}
        Vector-env mode (DESIGN.md §6): B independent learners vs one
        learner fed by all cells.
    episodes : int
        Default training episode count (paper: 500).
    warmup : int
        Stored slot transitions before D3PG minibatch updates begin.
    eps_start, eps_end, eps_decay_episodes : float, float, int
        DDQN epsilon-greedy schedule over episodes.
    lr_actor, lr_critic, lr_ddqn : float
        Adam learning rates (paper default 1e-6; see DESIGN.md §8 for the
        tuned CI-scale values).
    L : int
        Diffusion-actor denoising steps (paper Fig. 6a).
    seed : int
        Root PRNG seed for init and episode keys.
    ga : GACfg
        Genetic-algorithm parameters for the SCHRS baseline.
    """
    env: EnvCfg = EnvCfg()
    allocator: str = "d3pg"     # d3pg | ddpg | schrs | rcars
    cacher: str = "ddqn"        # ddqn | static | random
    policy: str = "independent"  # vector-env mode: independent | shared
    episodes: int = 500
    warmup: int = 200           # slot transitions before D3PG updates
    eps_start: float = 1.0      # DDQN epsilon-greedy schedule (per episode)
    eps_end: float = 0.05
    eps_decay_episodes: int = 300
    lr_actor: float = 1e-6      # paper default; benchmarks also run tuned lr
    lr_critic: float = 1e-6
    lr_ddqn: float = 1e-6
    L: int = 5                  # D3PG denoising steps
    seed: int = 0
    ga: GACfg = GACfg()

    def d3pg_cfg(self) -> D3PGCfg:
        return D3PGCfg(state_dim=self.env.state_dim,
                       action_dim=self.env.action_dim, L=self.L,
                       actor_kind="mlp" if self.allocator == "ddpg"
                       else "diffusion",
                       lr_actor=self.lr_actor, lr_critic=self.lr_critic)

    def ddqn_cfg(self) -> DDQNCfg:
        return DDQNCfg(M=self.env.M, J=len(self.env.gammas),
                       lr=self.lr_ddqn)


def t2drl_init(key, cfg: T2DRLCfg):
    km, kq, kd = jax.random.split(key, 3)
    env = cfg.env
    models = make_models(km, env)
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    S, A, U, M = env.state_dim, env.action_dim, env.U, env.M
    slot_item = {
        "s": jnp.zeros(S), "a": jnp.zeros(A), "r": jnp.float32(0.0),
        "s1": jnp.zeros(S), "req": jnp.zeros(U, jnp.int32),
        "rho": jnp.zeros(M), "req1": jnp.zeros(U, jnp.int32),
        "rho1": jnp.zeros(M),
    }
    frame_item = {"s": jnp.int32(0), "a": jnp.int32(0),
                  "r": jnp.float32(0.0), "s1": jnp.int32(0)}
    return {
        "models": models,
        "d3pg": d3pg_init(kd, d3),
        "ddqn": ddqn_init(kq, dq),
        "ebuf": buffer_init(d3.buffer, slot_item),
        "fbuf": buffer_init(dq.buffer, frame_item),
    }


def _batch_keys(key, num_envs: int):
    """Per-cell keys with the invariant cell0 == ``key``: cell 0 of any
    batch replays the legacy single-env run for the same seed."""
    if num_envs == 1:
        return key[None]
    return jnp.stack([key] + [jax.random.fold_in(key, i)
                              for i in range(1, num_envs)])


def t2drl_init_batch(key, cfg: T2DRLCfg, num_envs: int, *,
                     share_models: bool = False):
    """Train state for B parallel cells as one pytree.  Models and replay
    buffers always carry a leading (B,) axis; with ``cfg.policy ==
    "independent"`` the agent parameters do too (B fully independent
    seeds), while ``"shared"`` keeps ONE set of agent parameters (cell 0's
    init) learning from all cells' experience.

    Each cell draws its own model zoo (heterogeneous across the batch);
    ``share_models=True`` broadcasts cell 0's zoo to every cell instead
    (pure multi-seed variance studies on one scenario)."""
    if cfg.policy not in ("independent", "shared"):
        raise ValueError(f"unknown policy {cfg.policy!r}; "
                         "expected 'independent' or 'shared'")
    if num_envs < 1:
        raise ValueError("num_envs must be >= 1")
    ts = jax.vmap(lambda k: t2drl_init(k, cfg))(_batch_keys(key, num_envs))
    if share_models:
        ts["models"] = jax.tree.map(
            lambda x: jnp.repeat(x[:1], num_envs, axis=0), ts["models"])
    if cfg.policy == "shared":
        ts["d3pg"] = jax.tree.map(lambda x: x[0], ts["d3pg"])
        ts["ddqn"] = jax.tree.map(lambda x: x[0], ts["ddqn"])
    return ts


def episode_epsilon(cfg: T2DRLCfg, episode):
    frac = jnp.clip(episode / max(cfg.eps_decay_episodes, 1), 0.0, 1.0)
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


def episode_sigma(cfg: T2DRLCfg, episode):
    """Exploration-noise schedule: decays from explore_sigma to 0.02 on the
    same schedule as epsilon; zero for the non-learned allocators."""
    if cfg.allocator not in ("d3pg", "ddpg"):
        return jnp.float32(0.0)
    d3 = cfg.d3pg_cfg()
    frac = jnp.clip(episode / max(cfg.eps_decay_episodes, 1), 0.0, 1.0)
    return (d3.explore_sigma * (1.0 - frac) + 0.02 * frac).astype(jnp.float32)


def _episode_core(ts, cfg: T2DRLCfg, key, eps, sigma, *, train: bool = True,
                  mask=None, mods: Optional[ScenarioSchedule] = None):
    """One episode of Algorithm 1 for a single env.  ``mask`` is an optional
    (U,) 0/1 vector of active users (heterogeneous-population cells);
    ``mods`` an optional per-episode ScenarioSchedule (unbatched leaves)
    whose slices are fed to the env at every draw (DESIGN.md §9).  With
    ``mask=None, mods=None`` the computation is identical to the
    pre-vectorization ``run_episode``.  Returns (ts, stats)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    sched = make_actor_schedule(d3)
    models: ModelParams = ts["models"]
    k_env, key = jax.random.split(key)
    env = env_reset(k_env, env_cfg, schedule_slot_mod(mods, 0))

    def slot_step(carry, xs):
        k_slot, g = xs                 # g: global slot index t*K + k
        ts, env = carry
        ks = jax.random.split(k_slot, 4)
        s = observe(env, env_cfg, models, mask)
        if cfg.allocator in ("d3pg", "ddpg"):
            raw = actor_act(ts["d3pg"]["actor"], d3, sched, s, ks[0])
            raw = jnp.clip(raw + sigma * jax.random.normal(ks[1], raw.shape),
                           0.0, 1.0)
            b, xi = amend_actions(raw, env.req, env.rho, env_cfg.U, mask=mask)
        elif cfg.allocator == "schrs":
            b, xi = ga_allocate(ks[0], env, env_cfg, models, cfg.ga)
        else:  # rcars
            b, xi = rcars_allocate(env, env_cfg)
        env1, r, m = env_step_slot(env, env_cfg, models, b, xi, mask,
                                   schedule_slot_mod(mods, g + 1))
        new_ts = ts
        if cfg.allocator in ("d3pg", "ddpg"):
            s1 = observe(env1, env_cfg, models, mask)
            item = {"s": s, "a": jnp.concatenate([b, xi]), "r": r, "s1": s1,
                    "req": env.req, "rho": env.rho, "req1": env1.req,
                    "rho1": env1.rho}
            ebuf = buffer_add(ts["ebuf"], item)
            new_ts = {**ts, "ebuf": ebuf}
            if train:
                def do_update(ts_in):
                    batch = buffer_sample(ts_in["ebuf"], ks[2], d3.batch)
                    d3pg_new, _ = d3pg_update(ts_in["d3pg"], d3, sched,
                                              batch, ks[3], mask=mask)
                    return {**ts_in, "d3pg": d3pg_new}
                new_ts = jax.lax.cond(ebuf["size"] > cfg.warmup, do_update,
                                      lambda t: t, new_ts)
        stats = {"r": r, "hit": masked_mean(m["cached"], mask),
                 "G": masked_mean(m["G"], mask),
                 "delay": masked_mean(m["d_tl"], mask),
                 "quality": masked_mean(m["quality"], mask),
                 "viol": masked_mean(
                     (m["d_tl"] > env_cfg.tau).astype(jnp.float32), mask)}
        return (new_ts, env1), stats

    def frame_step(carry, xs):
        k_frame, t = xs                # t: frame index into the schedule
        ts, env = carry
        kf = jax.random.split(k_frame, 3)
        env = env_advance_frame(env, env_cfg, schedule_frame_P(mods, t),
                                schedule_slot_mod(mods, t * env_cfg.K))
        gamma_t = env.gamma_idx
        if cfg.cacher == "ddqn":
            a_int = ddqn_act(ts["ddqn"], dq, gamma_t, kf[0], eps)
            rho = amend_caching(a_int, dq, models.c, env_cfg.C)
        elif cfg.cacher == "static":
            a_int = jnp.int32(0)
            rho = static_popular_cache(models, env_cfg)
        else:  # random
            a_int = jnp.int32(0)
            rho = random_cache(kf[0], models, env_cfg)
        env = env_set_cache(env, rho)
        (ts, env), slot_stats = jax.lax.scan(
            slot_step, (ts, env),
            (jax.random.split(kf[1], env_cfg.K),
             t * env_cfg.K + jnp.arange(env_cfg.K)))
        # frame reward (32): average slot reward minus storage penalty
        # (erratum-corrected sign — see DESIGN.md §8)
        storage_viol = (jnp.sum(rho * models.c) > env_cfg.C).astype(jnp.float32)
        r_frame = jnp.mean(slot_stats["r"]) - storage_viol * env_cfg.Xi
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": slot_stats, "storage_viol": storage_viol}
        return (ts, env), out

    (ts, env), frames = jax.lax.scan(
        frame_step, (ts, env),
        (jax.random.split(key, env_cfg.T), jnp.arange(env_cfg.T)))

    # DDQN frame transitions: (gamma_t, a_t, r_t, gamma_{t+1}) for t < T-1
    if cfg.cacher == "ddqn" and train:
        def add_and_update(ts, t):
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add(ts["fbuf"], item)
            ts = {**ts, "fbuf": fbuf}
            def do_update(ts_in):
                kb = jax.random.fold_in(key, t)
                batch = buffer_sample(ts_in["fbuf"], kb, dq.batch)
                ddqn_new, _ = ddqn_update(ts_in["ddqn"], dq, batch)
                return {**ts_in, "ddqn": ddqn_new}
            ts = jax.lax.cond(fbuf["size"] > dq.batch, do_update,
                              lambda t_: t_, ts)
            return ts, None
        ts, _ = jax.lax.scan(add_and_update, ts,
                             jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]
    stats = {
        "episode_reward": jnp.sum(slot["r"]),
        "mean_reward": jnp.mean(slot["r"]),
        "hit_ratio": jnp.mean(slot["hit"]),
        "utility": jnp.mean(slot["G"]),
        "delay": jnp.mean(slot["delay"]),
        "quality": jnp.mean(slot["quality"]),
        "deadline_viol": jnp.mean(slot["viol"]),
        "storage_viol": jnp.mean(frames["storage_viol"]),
    }
    return ts, stats


@functools.partial(jax.jit, static_argnames=("cfg", "train"))
def run_episode(ts, cfg: T2DRLCfg, key, eps, sigma, *, train: bool = True,
                mods: Optional[ScenarioSchedule] = None):
    """One episode of Algorithm 1 (single env).  ``mods``: optional
    unbatched ScenarioSchedule (DESIGN.md §9).  Returns (ts, stats)."""
    return _episode_core(ts, cfg, key, eps, sigma, train=train, mods=mods)


def _batch_mean(x, masks=None):
    """Per-env mean over the trailing user axis; masks: (B, U) or None."""
    if masks is None:
        return jnp.mean(x, axis=-1)
    return jnp.sum(x * masks, axis=-1) / jnp.maximum(
        jnp.sum(masks, axis=-1), 1.0)


def _episode_core_shared(ts, cfg: T2DRLCfg, keys, eps, sigma, *,
                         train: bool = True, masks=None,
                         mods: Optional[ScenarioSchedule] = None):
    """One episode in shared-learner vector-env mode: B cells roll out in
    lockstep feeding per-cell replay buffers, and ONE shared policy takes a
    single optimizer step per slot on a fixed-size minibatch pooled evenly
    across the cells' buffers.  Per-step learner cost is independent of B —
    the standard vector-env trade (update:data ratio scales as 1/B).
    ``mods``: optional ScenarioSchedule with per-cell (B,)-leading leaves.
    Returns (ts, stats) with per-cell stats of shape (B,)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    sched = make_actor_schedule(d3)
    models: ModelParams = ts["models"]
    B = keys.shape[0]
    k_env = jax.vmap(lambda k: jax.random.split(k)[0])(keys)
    key = jax.random.split(keys[0])[1]     # driver key (frames, updates)
    env = env_reset_batch(k_env, env_cfg, schedule_slot_mod(mods, 0))
    n_slot = max(1, d3.batch // B)         # per-cell slice of the minibatch
    n_frame = max(1, dq.batch // B)
    row_masks = (None if masks is None
                 else jnp.repeat(masks, n_slot, axis=0))

    def pool(batch_be):
        """(B, n, ...) per-cell samples -> one (B*n, ...) minibatch."""
        return jax.tree.map(
            lambda x: x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:]),
            batch_be)

    def slot_step(carry, xs):
        k_slot, g = xs                 # g: global slot index t*K + k
        ts, env = carry
        ks = jax.random.split(k_slot, 4)
        s = jax.vmap(lambda e, m, mk: observe(e, env_cfg, m, mk))(
            env, models, masks)                               # (B, S)
        if cfg.allocator in ("d3pg", "ddpg"):
            raw = actor_act(ts["d3pg"]["actor"], d3, sched, s, ks[0])
            raw = jnp.clip(raw + sigma * jax.random.normal(ks[1], raw.shape),
                           0.0, 1.0)
            b, xi = amend_actions(raw, env.req, env.rho, env_cfg.U,
                                  mask=masks)
        elif cfg.allocator == "schrs":
            b, xi = jax.vmap(
                lambda k, e, m: ga_allocate(k, e, env_cfg, m, cfg.ga))(
                    jax.random.split(ks[0], B), env, models)
        else:  # rcars
            b, xi = jax.vmap(lambda e: rcars_allocate(e, env_cfg))(env)
        env1, r, m = jax.vmap(
            lambda e, mo, bb, xx, mk, md: env_step_slot(e, env_cfg, mo, bb,
                                                        xx, mk, md))(
            env, models, b, xi, masks, schedule_slot_mod(mods, g + 1))
        new_ts = ts
        if cfg.allocator in ("d3pg", "ddpg"):
            s1 = jax.vmap(lambda e, mo, mk: observe(e, env_cfg, mo, mk))(
                env1, models, masks)
            item = {"s": s, "a": jnp.concatenate([b, xi], axis=-1), "r": r,
                    "s1": s1, "req": env.req, "rho": env.rho,
                    "req1": env1.req, "rho1": env1.rho}
            ebuf = buffer_add_batch(ts["ebuf"], item)
            new_ts = {**ts, "ebuf": ebuf}
            if train:
                def do_update(ts_in):
                    batch = pool(buffer_sample_batch(
                        ts_in["ebuf"], jax.random.split(ks[2], B), n_slot))
                    d3pg_new, _ = d3pg_update(ts_in["d3pg"], d3, sched,
                                              batch, ks[3], mask=row_masks)
                    return {**ts_in, "d3pg": d3pg_new}
                new_ts = jax.lax.cond(
                    jnp.sum(ebuf["size"]) > cfg.warmup, do_update,
                    lambda t: t, new_ts)
        stats = {"r": r, "hit": _batch_mean(m["cached"], masks),
                 "G": _batch_mean(m["G"], masks),
                 "delay": _batch_mean(m["d_tl"], masks),
                 "quality": _batch_mean(m["quality"], masks),
                 "viol": _batch_mean(
                     (m["d_tl"] > env_cfg.tau).astype(jnp.float32), masks)}
        return (new_ts, env1), stats

    def frame_step(carry, xs):
        k_frame, t = xs                # t: frame index into the schedule
        ts, env = carry
        kf = jax.random.split(k_frame, 3)
        env = jax.vmap(lambda e, P, md: env_advance_frame(e, env_cfg, P, md))(
            env, schedule_frame_P(mods, t),
            schedule_slot_mod(mods, t * env_cfg.K))
        gamma_t = env.gamma_idx                               # (B,)
        if cfg.cacher == "ddqn":
            a_int = ddqn_act(ts["ddqn"], dq, gamma_t, kf[0], eps)
            rho = jax.vmap(
                lambda a, c: amend_caching(a, dq, c, env_cfg.C))(
                    a_int, models.c)                          # (B, M)
        elif cfg.cacher == "static":
            a_int = jnp.zeros((B,), jnp.int32)
            rho = static_popular_cache_batch(models, env_cfg)
        else:  # random
            a_int = jnp.zeros((B,), jnp.int32)
            rho = random_cache_batch(jax.random.split(kf[0], B), models,
                                     env_cfg)
        env = jax.vmap(env_set_cache)(env, rho)
        (ts, env), slot_stats = jax.lax.scan(
            slot_step, (ts, env),
            (jax.random.split(kf[1], env_cfg.K),
             t * env_cfg.K + jnp.arange(env_cfg.K)))
        storage_viol = (jnp.sum(rho * models.c, axis=-1)
                        > env_cfg.C).astype(jnp.float32)      # (B,)
        r_frame = jnp.mean(slot_stats["r"], axis=0) - storage_viol * env_cfg.Xi
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": slot_stats, "storage_viol": storage_viol}
        return (ts, env), out

    (ts, env), frames = jax.lax.scan(
        frame_step, (ts, env),
        (jax.random.split(key, env_cfg.T), jnp.arange(env_cfg.T)))

    if cfg.cacher == "ddqn" and train:
        def add_and_update(ts, t):
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add_batch(ts["fbuf"], item)
            ts = {**ts, "fbuf": fbuf}
            def do_update(ts_in):
                kb = jax.random.fold_in(key, t)
                batch = pool(buffer_sample_batch(
                    ts_in["fbuf"], jax.random.split(kb, B), n_frame))
                ddqn_new, _ = ddqn_update(ts_in["ddqn"], dq, batch)
                return {**ts_in, "ddqn": ddqn_new}
            ts = jax.lax.cond(jnp.sum(fbuf["size"]) > dq.batch, do_update,
                              lambda t_: t_, ts)
            return ts, None
        ts, _ = jax.lax.scan(add_and_update, ts,
                             jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]                  # leaves (T, K, B)
    stats = {
        "episode_reward": jnp.sum(slot["r"], axis=(0, 1)),
        "mean_reward": jnp.mean(slot["r"], axis=(0, 1)),
        "hit_ratio": jnp.mean(slot["hit"], axis=(0, 1)),
        "utility": jnp.mean(slot["G"], axis=(0, 1)),
        "delay": jnp.mean(slot["delay"], axis=(0, 1)),
        "quality": jnp.mean(slot["quality"], axis=(0, 1)),
        "deadline_viol": jnp.mean(slot["viol"], axis=(0, 1)),
        "storage_viol": jnp.mean(frames["storage_viol"], axis=0),
    }
    return ts, stats


def _episode_batch(ts, cfg: T2DRLCfg, keys, eps, sigma, *, train: bool,
                   masks=None, mods=None):
    """One episode across the batch; keys: (B,) per-cell episode keys.

    ``cfg.policy == "independent"`` vmaps the single-env episode (B
    independent learners); B=1 bypasses vmap so the single-env program (and
    its cond-based update gating) is preserved exactly.  ``"shared"``
    delegates to the shared-learner lockstep core.  ``mods``: optional
    ScenarioSchedule with per-cell (B,)-leading leaves."""
    if cfg.policy == "shared":
        return _episode_core_shared(ts, cfg, keys, eps, sigma, train=train,
                                    masks=masks, mods=mods)
    B = keys.shape[0]
    if B == 1:
        mask = None if masks is None else masks[0]
        mods1 = None if mods is None else jax.tree.map(lambda x: x[0], mods)
        ts1, stats = _episode_core(
            jax.tree.map(lambda x: x[0], ts), cfg, keys[0], eps, sigma,
            train=train, mask=mask, mods=mods1)
        expand = functools.partial(jax.tree.map, lambda x: x[None])
        return expand(ts1), expand(stats)
    return jax.vmap(
        lambda t, k, m, md: _episode_core(t, cfg, k, eps, sigma, train=train,
                                          mask=m, mods=md))(
        ts, keys, masks, mods)


@functools.partial(jax.jit, static_argnames=("cfg", "train"))
def run_training(ts, cfg: T2DRLCfg, key, ep_idx, masks=None, mods=None, *,
                 train: bool = True):
    """Scan ``_episode_batch`` over the (absolute) episode indices
    ``ep_idx`` — a whole multi-episode, multi-cell run in one compiled call.
    Epsilon/sigma schedules are traced functions of the episode index.
    ``mods``: optional ScenarioSchedule with per-cell (B,)-leading leaves,
    replayed every episode.  Returns (ts, history) with history leaves of
    shape (len(ep_idx), B)."""
    B = ts["models"].a1.shape[0]

    def ep_step(ts, ep):
        k_ep = jax.random.fold_in(key, ep)
        e = ep.astype(jnp.float32)
        eps = episode_epsilon(cfg, e)
        sigma = episode_sigma(cfg, e)
        return _episode_batch(ts, cfg, _batch_keys(k_ep, B), eps, sigma,
                              train=train, masks=masks, mods=mods)

    return jax.lax.scan(ep_step, ts, ep_idx)


@functools.partial(jax.jit, static_argnames=("cfg",))
def run_eval(ts, cfg: T2DRLCfg, key, ep_idx, masks=None, mods=None):
    """Greedy evaluation scan: eps = sigma = 0, no updates, ``ts`` is not
    threaded between episodes.  Returns history leaves (len(ep_idx), B)."""
    B = ts["models"].a1.shape[0]
    zero = jnp.float32(0.0)

    def ep_step(_, ep):
        k_ep = jax.random.fold_in(key, ep)
        _, stats = _episode_batch(ts, cfg, _batch_keys(k_ep, B), zero, zero,
                                  train=False, masks=masks, mods=mods)
        return None, stats

    _, stats = jax.lax.scan(ep_step, None, ep_idx)
    return stats


_ENV_AXIS_KEYS = ("models", "ebuf", "fbuf")   # always batched in batch mode


def _squeeze_env_axis(ts, cfg: T2DRLCfg):
    """Drop the leading B=1 axis, giving a legacy-shaped train state.  In
    shared-policy mode the agent parameters never had an env axis."""
    keys = (_ENV_AXIS_KEYS if cfg.policy == "shared" else ts.keys())
    return {k: (jax.tree.map(lambda x: x[0], v) if k in keys else v)
            for k, v in ts.items()}


def _expand_env_axis(ts, cfg: T2DRLCfg):
    keys = (_ENV_AXIS_KEYS if cfg.policy == "shared" else ts.keys())
    return {k: (jax.tree.map(lambda x: x[None], v) if k in keys else v)
            for k, v in ts.items()}


def _broadcast_mods(mods: Optional[ScenarioSchedule], num_envs: int):
    """Give an unbatched schedule a leading (num_envs,) cell axis (no-op for
    already-batched schedules or ``None``)."""
    if mods is None:
        return None
    if mods.h_scale.ndim == 2:
        if mods.h_scale.shape[0] != num_envs:
            raise ValueError(
                f"per-cell schedule was built for {mods.h_scale.shape[0]} "
                f"cells but num_envs={num_envs}; rebuild with "
                f"build_scenario(..., num_envs={num_envs})")
        return mods
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (num_envs,) + x.shape), mods)


def train_t2drl(cfg: T2DRLCfg, *, episodes: Optional[int] = None,
                num_envs: int = 1, user_counts: Optional[Sequence[int]] = None,
                share_models: bool = False, log_every: int = 0,
                callback=None, mods: Optional[ScenarioSchedule] = None):
    """Full training run over ``num_envs`` parallel edge cells (multi-seed).

    Parameters
    ----------
    cfg : T2DRLCfg
        Method + environment configuration (jit-static).
    episodes : int, optional
        Episode count (defaults to ``cfg.episodes``).
    num_envs : int
        Number of parallel edge cells B trained through the vectorized core
        (DESIGN.md §6).  ``cfg.policy`` selects independent vs shared
        learners.
    user_counts : sequence of int, optional
        Per-cell active-user counts (len ``num_envs``) — heterogeneous
        populations via masking.
    share_models : bool
        Broadcast cell 0's model zoo to every cell (pure multi-seed runs).
    log_every : int
        Print a progress line every N episodes (chunks the episode scan;
        results are unchanged because keys derive from absolute indices).
    callback : callable, optional
        ``callback(episode, mean_stats)`` after every episode.
    mods : ScenarioSchedule, optional
        Scenario modulation schedule (DESIGN.md §9), e.g. from
        ``repro.scenarios.build_scenario``.  Unbatched leaves are broadcast
        to all cells; per-cell leaves (leading ``(num_envs,)`` axis) give
        heterogeneous scenarios.

    Returns
    -------
    (dict, dict)
        Final train-state pytree and history dict of stacked arrays.
        History leaves have shape ``(episodes,)`` for ``num_envs=1``
        (legacy layout) and ``(episodes, num_envs)`` otherwise; likewise
        the train state keeps its leading batch axis only for
        ``num_envs > 1``.
    """
    episodes = episodes or cfg.episodes
    key = jax.random.PRNGKey(cfg.seed)
    k_init, key = jax.random.split(key)
    ts = t2drl_init_batch(k_init, cfg, num_envs, share_models=share_models)
    masks = None
    if user_counts is not None:
        if len(user_counts) != num_envs:
            raise ValueError("user_counts must have one entry per env")
        masks = make_user_masks(cfg.env, user_counts)
    mods = _broadcast_mods(mods, num_envs)
    chunk = episodes if not (log_every or callback) else (log_every or 1)
    chunks, ep0 = [], 0
    while ep0 < episodes:
        n = min(chunk, episodes - ep0)
        ts, stats = run_training(ts, cfg, key, jnp.arange(ep0, ep0 + n),
                                 masks, mods, train=True)
        chunks.append(stats)
        if log_every:
            last = {k: float(jnp.mean(v[-1])) for k, v in stats.items()}
            print(f"ep {ep0 + n:4d} reward {last['episode_reward']:9.2f} "
                  f"hit {last['hit_ratio']:.3f} "
                  f"G {last['utility']:7.2f}")
        if callback is not None:
            for i in range(n):
                callback(ep0 + i,
                         jax.tree.map(lambda x: jnp.mean(x[i]), stats))
        ep0 += n
    history = {k: jnp.concatenate([c[k] for c in chunks])
               for k in chunks[0]}
    if num_envs == 1:
        ts = _squeeze_env_axis(ts, cfg)
        history = {k: v[:, 0] for k, v in history.items()}
    return ts, history


def eval_t2drl(ts, cfg: T2DRLCfg, *, episodes: int = 10, seed: int = 10_000,
               user_counts: Optional[Sequence[int]] = None,
               mods: Optional[ScenarioSchedule] = None):
    """Greedy evaluation (no exploration, no updates).

    Parameters
    ----------
    ts : dict
        Train-state pytree — single (legacy layout) or batched (leading
        ``(B,)`` axis, as returned by ``train_t2drl(..., num_envs=B)``).
    cfg : T2DRLCfg
        Method + environment configuration (jit-static).
    episodes : int
        Number of greedy evaluation episodes.
    seed : int
        PRNG seed for the evaluation episode keys (disjoint from training
        seeds by default).
    user_counts : sequence of int, optional
        Per-cell active-user counts (one entry per cell in ``ts``).
    mods : ScenarioSchedule, optional
        Scenario modulation schedule; unbatched leaves are broadcast to all
        cells.  Evaluating under a different schedule than training
        measures out-of-scenario generalization.

    Returns
    -------
    dict
        Scalar means over episodes and cells: ``episode_reward``,
        ``mean_reward``, ``hit_ratio``, ``utility``, ``delay``,
        ``quality``, ``deadline_viol``, ``storage_viol``.
    """
    batched = ts["models"].a1.ndim == 2
    if not batched:
        ts = _expand_env_axis(ts, cfg)
    B = ts["models"].a1.shape[0]
    masks = None
    if user_counts is not None:
        if len(user_counts) != B:
            raise ValueError("user_counts must have one entry per env")
        masks = make_user_masks(cfg.env, user_counts)
    stats = run_eval(ts, cfg, jax.random.PRNGKey(seed),
                     jnp.arange(episodes), masks, _broadcast_mods(mods, B))
    return {k: jnp.mean(v) for k, v in stats.items()}


# -- policy deployment (inference-only, DESIGN.md §11) ------------------------
#
# ``export_policy`` slices the learner-free parameters out of a train state
# so a trained policy can be checkpointed (repro.checkpoint.save_train_state)
# and served — e.g. by the request-level fleet twin (repro.fleet) — without
# dragging replay buffers, target networks, or optimizer moments along.
# ``greedy_slot_action`` / ``greedy_frame_cache`` are the single-env greedy
# inference entry points every allocator/cacher combination shares.


def export_policy(ts, cfg: T2DRLCfg, cell: int = 0):
    """Extract the inference-only policy pytree from a train state.

    Parameters
    ----------
    ts : dict
        Train state — legacy single-env layout or batched (leading ``(B,)``
        axis) as returned by ``train_t2drl(..., num_envs=B)``.
    cfg : T2DRLCfg
        The configuration the state was trained under (selects which agent
        parameters exist).
    cell : int
        For batched *independent*-policy states, which cell's learner to
        export.  Shared-policy states have a single learner; ``cell`` is
        then ignored and the shared parameters are taken as-is.

    Returns
    -------
    dict
        ``{"actor": ..., "ddqn": {"q": ...}}`` with keys present only for
        the learned components of ``cfg`` (empty dict for RCARS/SCHRS).
        Model zoos are *not* included — they are environment state, passed
        to the twin separately.
    """
    batched_agents = (ts["models"].a1.ndim == 2 and cfg.policy != "shared")
    take = ((lambda x: jax.tree.map(lambda v: v[cell], x))
            if batched_agents else (lambda x: x))
    pol = {}
    if cfg.allocator in ("d3pg", "ddpg"):
        pol["actor"] = take(ts["d3pg"]["actor"])
    if cfg.cacher == "ddqn":
        pol["ddqn"] = {"q": take(ts["ddqn"]["q"])}
    return pol


def greedy_slot_action(policy, cfg: T2DRLCfg, env: EnvState,
                       models: ModelParams, key, mask=None):
    """Greedy (no exploration noise) per-slot allocation for any allocator.

    Returns the amended ``(b, xi)`` exactly as the training-time slot step
    would under ``sigma = 0``; ``key`` drives the diffusion actor's reverse
    chain (D3PG) or the GA (SCHRS)."""
    if cfg.allocator in ("d3pg", "ddpg"):
        d3 = cfg.d3pg_cfg()
        sched = make_actor_schedule(d3)
        s = observe(env, cfg.env, models, mask)
        raw = actor_act(policy["actor"], d3, sched, s, key)
        return amend_actions(raw, env.req, env.rho, cfg.env.U, mask=mask)
    if cfg.allocator == "schrs":
        return ga_allocate(key, env, cfg.env, models, cfg.ga)
    return rcars_allocate(env, cfg.env)


def greedy_frame_cache(policy, cfg: T2DRLCfg, models: ModelParams,
                       gamma_idx, key):
    """Greedy (eps = 0) per-frame caching vector rho for any cacher."""
    if cfg.cacher == "ddqn":
        dq = cfg.ddqn_cfg()
        a_int = ddqn_act(policy["ddqn"], dq, gamma_idx, key, 0.0)
        return amend_caching(a_int, dq, models.c, cfg.env.C)
    if cfg.cacher == "static":
        return static_popular_cache(models, cfg.env)
    return random_cache(key, models, cfg.env)
