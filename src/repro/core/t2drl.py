"""T2DRL — the paper's Algorithm 1: outer long-timescale DDQN (caching) +
inner short-timescale D3PG (resource allocation), fully jitted per episode.

``allocator``/``cacher`` select the agent combination, covering the paper's
benchmarks:

  T2DRL             allocator="d3pg",  cacher="ddqn"
  DDPG-based T2DRL  allocator="ddpg",  cacher="ddqn"
  SCHRS             allocator="schrs", cacher="static"
  RCARS             allocator="rcars", cacher="random"
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .baselines import (GACfg, ga_allocate, random_cache, rcars_allocate,
                        static_popular_cache)
from .buffers import buffer_add, buffer_init, buffer_sample
from .d3pg import (D3PGCfg, actor_act, amend_actions, d3pg_init, d3pg_update,
                   make_actor_schedule)
from .ddqn import DDQNCfg, amend_caching, ddqn_act, ddqn_init, ddqn_update
from .env import (EnvCfg, EnvState, ModelParams, env_advance_frame,
                  env_reset, env_set_cache, env_step_slot, make_models,
                  observe)


@dataclasses.dataclass(frozen=True)
class T2DRLCfg:
    env: EnvCfg = EnvCfg()
    allocator: str = "d3pg"     # d3pg | ddpg | schrs | rcars
    cacher: str = "ddqn"        # ddqn | static | random
    episodes: int = 500
    warmup: int = 200           # slot transitions before D3PG updates
    eps_start: float = 1.0      # DDQN epsilon-greedy schedule (per episode)
    eps_end: float = 0.05
    eps_decay_episodes: int = 300
    lr_actor: float = 1e-6      # paper default; benchmarks also run tuned lr
    lr_critic: float = 1e-6
    lr_ddqn: float = 1e-6
    L: int = 5                  # D3PG denoising steps
    seed: int = 0
    ga: GACfg = GACfg()

    def d3pg_cfg(self) -> D3PGCfg:
        return D3PGCfg(state_dim=self.env.state_dim,
                       action_dim=self.env.action_dim, L=self.L,
                       actor_kind="mlp" if self.allocator == "ddpg"
                       else "diffusion",
                       lr_actor=self.lr_actor, lr_critic=self.lr_critic)

    def ddqn_cfg(self) -> DDQNCfg:
        return DDQNCfg(M=self.env.M, J=len(self.env.gammas),
                       lr=self.lr_ddqn)


def t2drl_init(key, cfg: T2DRLCfg):
    km, kq, kd = jax.random.split(key, 3)
    env = cfg.env
    models = make_models(km, env)
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    S, A, U, M = env.state_dim, env.action_dim, env.U, env.M
    slot_item = {
        "s": jnp.zeros(S), "a": jnp.zeros(A), "r": jnp.float32(0.0),
        "s1": jnp.zeros(S), "req": jnp.zeros(U, jnp.int32),
        "rho": jnp.zeros(M), "req1": jnp.zeros(U, jnp.int32),
        "rho1": jnp.zeros(M),
    }
    frame_item = {"s": jnp.int32(0), "a": jnp.int32(0),
                  "r": jnp.float32(0.0), "s1": jnp.int32(0)}
    return {
        "models": models,
        "d3pg": d3pg_init(kd, d3),
        "ddqn": ddqn_init(kq, dq),
        "ebuf": buffer_init(d3.buffer, slot_item),
        "fbuf": buffer_init(dq.buffer, frame_item),
    }


def episode_epsilon(cfg: T2DRLCfg, episode):
    frac = jnp.clip(episode / max(cfg.eps_decay_episodes, 1), 0.0, 1.0)
    return cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac


@functools.partial(jax.jit, static_argnames=("cfg", "train"))
def run_episode(ts, cfg: T2DRLCfg, key, eps, sigma, *, train: bool = True):
    """One episode of Algorithm 1.  Returns (ts, stats)."""
    env_cfg = cfg.env
    d3 = cfg.d3pg_cfg()
    dq = cfg.ddqn_cfg()
    sched = make_actor_schedule(d3)
    models: ModelParams = ts["models"]
    k_env, key = jax.random.split(key)
    env = env_reset(k_env, env_cfg)

    def slot_step(carry, k_slot):
        ts, env = carry
        ks = jax.random.split(k_slot, 4)
        s = observe(env, env_cfg, models)
        if cfg.allocator in ("d3pg", "ddpg"):
            raw = actor_act(ts["d3pg"]["actor"], d3, sched, s, ks[0])
            raw = jnp.clip(raw + sigma * jax.random.normal(ks[1], raw.shape),
                           0.0, 1.0)
            b, xi = amend_actions(raw, env.req, env.rho, env_cfg.U)
        elif cfg.allocator == "schrs":
            b, xi = ga_allocate(ks[0], env, env_cfg, models, cfg.ga)
        else:  # rcars
            b, xi = rcars_allocate(env, env_cfg)
        env1, r, m = env_step_slot(env, env_cfg, models, b, xi)
        new_ts = ts
        if cfg.allocator in ("d3pg", "ddpg"):
            s1 = observe(env1, env_cfg, models)
            item = {"s": s, "a": jnp.concatenate([b, xi]), "r": r, "s1": s1,
                    "req": env.req, "rho": env.rho, "req1": env1.req,
                    "rho1": env1.rho}
            ebuf = buffer_add(ts["ebuf"], item)
            new_ts = {**ts, "ebuf": ebuf}
            if train:
                def do_update(ts_in):
                    batch = buffer_sample(ts_in["ebuf"], ks[2], d3.batch)
                    d3pg_new, _ = d3pg_update(ts_in["d3pg"], d3, sched,
                                              batch, ks[3])
                    return {**ts_in, "d3pg": d3pg_new}
                new_ts = jax.lax.cond(ebuf["size"] > cfg.warmup, do_update,
                                      lambda t: t, new_ts)
        stats = {"r": r, "hit": jnp.mean(m["cached"]),
                 "G": jnp.mean(m["G"]),
                 "delay": jnp.mean(m["d_tl"]),
                 "quality": jnp.mean(m["quality"]),
                 "viol": jnp.mean((m["d_tl"] > env_cfg.tau).astype(jnp.float32))}
        return (new_ts, env1), stats

    def frame_step(carry, k_frame):
        ts, env = carry
        kf = jax.random.split(k_frame, 3)
        env = env_advance_frame(env, env_cfg)
        gamma_t = env.gamma_idx
        if cfg.cacher == "ddqn":
            a_int = ddqn_act(ts["ddqn"], dq, gamma_t, kf[0], eps)
            rho = amend_caching(a_int, dq, models.c, env_cfg.C)
        elif cfg.cacher == "static":
            a_int = jnp.int32(0)
            rho = static_popular_cache(models, env_cfg)
        else:  # random
            a_int = jnp.int32(0)
            rho = random_cache(kf[0], models, env_cfg)
        env = env_set_cache(env, rho)
        (ts, env), slot_stats = jax.lax.scan(
            slot_step, (ts, env), jax.random.split(kf[1], env_cfg.K))
        # frame reward (32): average slot reward minus storage penalty
        # (erratum-corrected sign — see DESIGN.md §8)
        storage_viol = (jnp.sum(rho * models.c) > env_cfg.C).astype(jnp.float32)
        r_frame = jnp.mean(slot_stats["r"]) - storage_viol * env_cfg.Xi
        out = {"gamma": gamma_t, "a_int": a_int, "r_frame": r_frame,
               "slot": slot_stats, "storage_viol": storage_viol}
        return (ts, env), out

    (ts, env), frames = jax.lax.scan(
        frame_step, (ts, env), jax.random.split(key, env_cfg.T))

    # DDQN frame transitions: (gamma_t, a_t, r_t, gamma_{t+1}) for t < T-1
    if cfg.cacher == "ddqn" and train:
        def add_and_update(ts, t):
            item = {"s": frames["gamma"][t], "a": frames["a_int"][t],
                    "r": frames["r_frame"][t], "s1": frames["gamma"][t + 1]}
            fbuf = buffer_add(ts["fbuf"], item)
            ts = {**ts, "fbuf": fbuf}
            def do_update(ts_in):
                kb = jax.random.fold_in(key, t)
                batch = buffer_sample(ts_in["fbuf"], kb, dq.batch)
                ddqn_new, _ = ddqn_update(ts_in["ddqn"], dq, batch)
                return {**ts_in, "ddqn": ddqn_new}
            ts = jax.lax.cond(fbuf["size"] > dq.batch, do_update,
                              lambda t_: t_, ts)
            return ts, None
        ts, _ = jax.lax.scan(add_and_update, ts,
                             jnp.arange(env_cfg.T - 1))

    slot = frames["slot"]
    stats = {
        "episode_reward": jnp.sum(slot["r"]),
        "mean_reward": jnp.mean(slot["r"]),
        "hit_ratio": jnp.mean(slot["hit"]),
        "utility": jnp.mean(slot["G"]),
        "delay": jnp.mean(slot["delay"]),
        "quality": jnp.mean(slot["quality"]),
        "deadline_viol": jnp.mean(slot["viol"]),
        "storage_viol": jnp.mean(frames["storage_viol"]),
    }
    return ts, stats


def train_t2drl(cfg: T2DRLCfg, *, episodes: Optional[int] = None,
                log_every: int = 0, callback=None):
    """Full training run.  Returns (train_state, history dict of arrays)."""
    episodes = episodes or cfg.episodes
    key = jax.random.PRNGKey(cfg.seed)
    k_init, key = jax.random.split(key)
    ts = t2drl_init(k_init, cfg)
    hist = []
    d3 = cfg.d3pg_cfg()
    for ep in range(episodes):
        k_ep = jax.random.fold_in(key, ep)
        eps = episode_epsilon(cfg, jnp.float32(ep))
        # exploration noise decays on the same schedule as epsilon
        frac = min(ep / max(cfg.eps_decay_episodes, 1), 1.0)
        sigma = jnp.float32(
            (d3.explore_sigma * (1.0 - frac) + 0.02 * frac)
            if cfg.allocator in ("d3pg", "ddpg") else 0.0)
        ts, stats = run_episode(ts, cfg, k_ep, eps, sigma, train=True)
        hist.append(stats)
        if log_every and (ep + 1) % log_every == 0:
            print(f"ep {ep + 1:4d} reward {float(stats['episode_reward']):9.2f} "
                  f"hit {float(stats['hit_ratio']):.3f} "
                  f"G {float(stats['utility']):7.2f}")
        if callback is not None:
            callback(ep, stats)
    history = {k: jnp.stack([h[k] for h in hist]) for k in hist[0]}
    return ts, history


def eval_t2drl(ts, cfg: T2DRLCfg, *, episodes: int = 10, seed: int = 10_000):
    """Greedy evaluation (no exploration, no updates)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for ep in range(episodes):
        k_ep = jax.random.fold_in(key, ep)
        _, stats = run_episode(ts, cfg, k_ep, jnp.float32(0.0),
                               jnp.float32(0.0), train=False)
        out.append(stats)
    return {k: jnp.mean(jnp.stack([o[k] for o in out])) for k in out[0]}
