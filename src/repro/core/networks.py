"""Plain MLP utilities for critic / Q networks (paper Sec. 7.1 topology).

Two apply paths (DESIGN.md §13): the per-learner ``mlp_apply`` and the
fused ``mlp_apply_stacked`` over B stacked parameter sets — every leaf
carries a leading ``(B,)`` learner axis and the whole stack advances
through one batched ``(B, ..., in) × (B, in, out)`` contraction per layer
instead of B small per-learner matmuls.  Both paths are bit-identical to
``jax.vmap`` of the per-learner apply (pinned by ``tests/test_fused.py``).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlp_init(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (i, o)) / math.sqrt(i)).astype(jnp.float32),
             "b": jnp.zeros(o)}
            for k, i, o in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x, *, final_act=None):
    for layer in layers[:-1]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    x = x @ layers[-1]["w"] + layers[-1]["b"]
    if final_act is not None:
        x = final_act(x)
    return x


def mlp_init_stacked(keys, dims):
    """B independent MLPs as one stacked pytree: leaves ``(B, in, out)`` /
    ``(B, out)``.  ``keys``: (B, 2) per-learner init keys.  Init is not a
    hot path — the stack is built by vmapping the per-learner init, which
    fixes the canonical stacked layout every fused path assumes."""
    return jax.vmap(lambda k: mlp_init(k, dims))(keys)


def stacked_linear(x, w, b):
    """``x @ w + b`` with a leading learner axis on the parameters.

    x: ``(B, ..., i)``; w: ``(B, i, o)``; b: ``(B, o)`` -> ``(B, ..., o)``.
    One batched contraction for all B learners — the einsum lowers to the
    same batch-dim ``dot_general`` ``jax.vmap`` of ``x @ w`` produces, so
    the fused path stays bit-identical to the vmap reference."""
    y = jnp.einsum("b...i,bio->b...o", x, w)
    return y + b.reshape((b.shape[0],) + (1,) * (y.ndim - 2) + (b.shape[-1],))


def mlp_apply_stacked(layers, x, *, final_act=None):
    """``mlp_apply`` over B stacked parameter sets (leading ``(B,)`` on
    every leaf); x: ``(B, ..., in)`` -> ``(B, ..., out)``."""
    for layer in layers[:-1]:
        x = jax.nn.relu(stacked_linear(x, layer["w"], layer["b"]))
    x = stacked_linear(x, layers[-1]["w"], layers[-1]["b"])
    if final_act is not None:
        x = final_act(x)
    return x


def soft_update(target, online, rate):
    """Polyak averaging, Eqs. (28)-(29)/(35)."""
    return jax.tree.map(lambda t, o: (1.0 - rate) * t + rate * o,
                        target, online)
