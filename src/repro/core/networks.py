"""Plain MLP utilities for critic / Q networks (paper Sec. 7.1 topology)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mlp_init(key, dims):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (i, o)) / math.sqrt(i)).astype(jnp.float32),
             "b": jnp.zeros(o)}
            for k, i, o in zip(keys, dims[:-1], dims[1:])]


def mlp_apply(layers, x, *, final_act=None):
    for layer in layers[:-1]:
        x = jax.nn.relu(x @ layer["w"] + layer["b"])
    x = x @ layers[-1]["w"] + layers[-1]["b"]
    if final_act is not None:
        x = final_act(x)
    return x


def soft_update(target, online, rate):
    """Polyak averaging, Eqs. (28)-(29)/(35)."""
    return jax.tree.map(lambda t, o: (1.0 - rate) * t + rate * o,
                        target, online)
