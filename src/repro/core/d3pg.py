"""D3PG — diffusion-based deep deterministic policy gradient (paper Sec. 6.2).

The actor is a conditional DDPM reverse chain (``repro.diffusion``): action =
L denoising steps from N(0, I), conditioned on the slot state s_t(k).  The
critic is the paper's 2×256 MLP.  Training backpropagates the deterministic
policy gradient (26) through the whole reverse chain.  Setting
``actor_kind="mlp"`` recovers the DDPG-based T2DRL baseline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.diffusion import (denoiser_init, make_schedule,
                             reverse_sample_actions,
                             reverse_sample_actions_stacked,
                             reverse_sample_actions_stacked_stats,
                             reverse_sample_actions_stats)
from repro.optim import adam_init, adam_update, adam_update_stacked
from .ddqn import _tree_l2, _tree_l2_stacked
from .networks import (mlp_apply, mlp_apply_stacked, mlp_init, soft_update)


@dataclasses.dataclass(frozen=True)
class D3PGCfg:
    state_dim: int
    action_dim: int
    L: int = 5                       # denoising steps (paper Fig. 6a -> 5)
    actor_kind: str = "diffusion"    # "diffusion" (D3PG) | "mlp" (DDPG)
    actor_hidden: int = 128          # paper: 3 FC layers of 128 (denoiser)
    actor_layers: int = 3
    critic_hidden: int = 256         # paper: 2 FC layers of 256
    critic_layers: int = 2
    lr_actor: float = 1e-6
    lr_critic: float = 1e-6
    omega: float = 0.95              # discount
    eps_target: float = 0.005        # target update rate (28)-(29)
    batch: int = 64
    buffer: int = 10000
    beta_min: float = 0.1
    beta_max: float = 10.0
    explore_sigma: float = 0.1       # Gaussian exploration on raw actions


def make_actor_schedule(cfg: D3PGCfg):
    return make_schedule(cfg.L, beta_min=cfg.beta_min, beta_max=cfg.beta_max,
                         kind="paper")


def d3pg_init(key, cfg: D3PGCfg):
    ka, kc = jax.random.split(key)
    if cfg.actor_kind == "diffusion":
        actor = denoiser_init(ka, cfg.state_dim, cfg.action_dim,
                              hidden=cfg.actor_hidden,
                              n_layers=cfg.actor_layers)
    else:
        dims = ([cfg.state_dim] + [cfg.actor_hidden] * cfg.actor_layers
                + [cfg.action_dim])
        actor = mlp_init(ka, dims)
    critic = mlp_init(kc, [cfg.state_dim + cfg.action_dim]
                      + [cfg.critic_hidden] * cfg.critic_layers + [1])
    return {"actor": actor,
            "actor_t": jax.tree.map(jnp.copy, actor),
            "critic": critic,
            "critic_t": jax.tree.map(jnp.copy, critic),
            "opt_a": adam_init(actor), "opt_c": adam_init(critic)}


def actor_act(actor_params, cfg: D3PGCfg, sched, state, key, *,
              impl: str = "xla"):
    """Raw action in [0,1]^A.  state: (..., S)."""
    if cfg.actor_kind == "diffusion":
        return reverse_sample_actions(actor_params, sched, state, key,
                                      cfg.action_dim, impl=impl)
    x = mlp_apply(actor_params, state, final_act=jnp.tanh)
    return 0.5 * (x + 1.0)


def critic_q(critic_params, state, action):
    return mlp_apply(critic_params, jnp.concatenate([state, action],
                                                    axis=-1))[..., 0]


def amend_actions(raw, req, rho, U: int, *, b_floor: float = 0.01,
                  mask=None):
    """The paper's action amender: project raw [0,1]^{2U} onto the bandwidth
    simplex (11e) and the cache-gated compute simplex (11f)-(11g).

    ``b_floor`` adds a small pseudo-count before normalising the bandwidth
    shares: a raw share of exactly 0 would give a user zero rate and an
    unbounded upload delay (Eq. 2 -> Eq. 4), which explodes the reward scale
    and destabilises the critic.  This is a numerical guard, not a change to
    the constraint set — the amended b still lies on the simplex (11e).

    ``mask`` (0/1 over the trailing user axis) restricts both simplexes to
    the active users of a heterogeneous-population cell: inactive users get
    exactly zero bandwidth and compute."""
    b_t, xi_t = raw[..., :U], raw[..., U:]
    b_t = b_t + b_floor
    if mask is not None:
        b_t = b_t * mask
    b = b_t / (jnp.sum(b_t, axis=-1, keepdims=True) + 1e-9)
    gate = rho[..., req] if rho.ndim == 1 else jnp.take_along_axis(rho, req, axis=-1)
    if mask is not None:
        gate = gate * mask
    xi = xi_t * gate / (jnp.sum(gate * xi_t, axis=-1, keepdims=True) + 1e-9)
    return b, xi


def d3pg_diag_zero(cfg: D3PGCfg) -> dict:
    """Zeros pytree matching the diag metrics of ``d3pg_update(diag=True)``
    (the skipped-update branch of the in-scan ``lax.cond`` tap).  The
    ``denoise_mag`` leaf — per-step mean |eps_hat| of the target actor's
    reverse chain, (L,) — exists only for the diffusion actor."""
    z = jnp.zeros((), jnp.float32)
    out = {"critic_loss": z, "actor_loss": z, "q_mean": z,
           "td_abs_mean": z, "td_abs_max": z,
           "actor_grad_norm": z, "critic_grad_norm": z}
    if cfg.actor_kind == "diffusion":
        out["denoise_mag"] = jnp.zeros((cfg.L,), jnp.float32)
    return out


def d3pg_update(params, cfg: D3PGCfg, sched, batch, key, *,
                lr_a=None, lr_c=None, impl: str = "xla", mask=None,
                diag=False):
    """One minibatch step of Eqs. (24)-(29).

    batch: {s, a, r, s1, req1, rho1} — a is the *amended* action executed;
    the target action for s1 is re-amended using req1/rho1.  ``mask`` is an
    active-user mask — (U,) shared across the minibatch, or (batch, U)
    per-row when the rows come from different cells — so target and policy
    actions are amended on the same restricted simplex the env ran on.

    ``diag=True`` (telemetry, DESIGN.md §15) extends the metrics dict with
    critic Q/TD statistics, gradient norms, and (diffusion actor) the
    per-step denoising magnitudes of the target chain; the diag chain uses
    the XLA step math regardless of ``impl``.  The ``diag=False`` path is
    deliberately left byte-identical to the pre-telemetry build."""
    if diag:
        return _d3pg_update_diag(params, cfg, sched, batch, key,
                                 lr_a=lr_a, lr_c=lr_c, mask=mask)
    lr_a = cfg.lr_actor if lr_a is None else lr_a
    lr_c = cfg.lr_critic if lr_c is None else lr_c
    k_t, k_pi = jax.random.split(key)
    U = cfg.action_dim // 2
    if mask is not None and jnp.ndim(mask) == 2:
        _amend_row = jax.vmap(
            lambda raw, req, rho, m: amend_actions(raw, req, rho, U, mask=m))
        amend = lambda raw, req, rho: _amend_row(raw, req, rho, mask)
    else:
        amend = jax.vmap(lambda raw, req, rho: amend_actions(
            raw, req, rho, U, mask=mask))

    # --- critic (24) ---------------------------------------------------------
    raw1 = actor_act(params["actor_t"], cfg, sched, batch["s1"], k_t,
                     impl=impl)
    b1, xi1 = amend(raw1, batch["req1"], batch["rho1"])
    a1 = jnp.concatenate([b1, xi1], axis=-1)
    y_hat = batch["r"] + cfg.omega * critic_q(params["critic_t"],
                                              batch["s1"], a1)
    y_hat = jax.lax.stop_gradient(y_hat)

    def critic_loss(c):
        y = critic_q(c, batch["s"], batch["a"])
        return jnp.mean(0.5 * (y_hat - y) ** 2)

    c_loss, c_grads = jax.value_and_grad(critic_loss)(params["critic"])
    critic_new, opt_c_new, _ = adam_update(c_grads, params["opt_c"],
                                           params["critic"], lr=lr_c)

    # --- actor (26)-(27): maximise Q(s, amend(pi(s))) ------------------------
    def actor_loss(a_params):
        raw = actor_act(a_params, cfg, sched, batch["s"], k_pi, impl=impl)
        b, xi = amend(raw, batch["req"], batch["rho"])
        act = jnp.concatenate([b, xi], axis=-1)
        return -jnp.mean(critic_q(critic_new, batch["s"], act))

    a_loss, a_grads = jax.value_and_grad(actor_loss)(params["actor"])
    actor_new, opt_a_new, _ = adam_update(a_grads, params["opt_a"],
                                          params["actor"], lr=lr_a)

    new = {"actor": actor_new,
           "actor_t": soft_update(params["actor_t"], actor_new,
                                  cfg.eps_target),
           "critic": critic_new,
           "critic_t": soft_update(params["critic_t"], critic_new,
                                   cfg.eps_target),
           "opt_a": opt_a_new, "opt_c": opt_c_new}
    return new, {"critic_loss": c_loss, "actor_loss": a_loss}


def _d3pg_update_diag(params, cfg: D3PGCfg, sched, batch, key, *,
                      lr_a=None, lr_c=None, mask=None):
    """``d3pg_update`` with the telemetry tap: same math and PRNG stream,
    plus diagnostics (keys pinned by ``d3pg_diag_zero``)."""
    lr_a = cfg.lr_actor if lr_a is None else lr_a
    lr_c = cfg.lr_critic if lr_c is None else lr_c
    k_t, k_pi = jax.random.split(key)
    U = cfg.action_dim // 2
    if mask is not None and jnp.ndim(mask) == 2:
        _amend_row = jax.vmap(
            lambda raw, req, rho, m: amend_actions(raw, req, rho, U, mask=m))
        amend = lambda raw, req, rho: _amend_row(raw, req, rho, mask)
    else:
        amend = jax.vmap(lambda raw, req, rho: amend_actions(
            raw, req, rho, U, mask=mask))

    # --- critic (24), tapping the target chain's denoising magnitudes --------
    if cfg.actor_kind == "diffusion":
        raw1, chain = reverse_sample_actions_stats(
            params["actor_t"], sched, batch["s1"], k_t, cfg.action_dim)
    else:
        raw1 = actor_act(params["actor_t"], cfg, sched, batch["s1"], k_t)
        chain = {}
    b1, xi1 = amend(raw1, batch["req1"], batch["rho1"])
    a1 = jnp.concatenate([b1, xi1], axis=-1)
    y_hat = batch["r"] + cfg.omega * critic_q(params["critic_t"],
                                              batch["s1"], a1)
    y_hat = jax.lax.stop_gradient(y_hat)

    def critic_loss(c):
        y = critic_q(c, batch["s"], batch["a"])
        return jnp.mean(0.5 * (y_hat - y) ** 2), y

    (c_loss, y), c_grads = jax.value_and_grad(
        critic_loss, has_aux=True)(params["critic"])
    critic_new, opt_c_new, _ = adam_update(c_grads, params["opt_c"],
                                           params["critic"], lr=lr_c)

    # --- actor (26)-(27) -----------------------------------------------------
    def actor_loss(a_params):
        raw = actor_act(a_params, cfg, sched, batch["s"], k_pi)
        b, xi = amend(raw, batch["req"], batch["rho"])
        act = jnp.concatenate([b, xi], axis=-1)
        return -jnp.mean(critic_q(critic_new, batch["s"], act))

    a_loss, a_grads = jax.value_and_grad(actor_loss)(params["actor"])
    actor_new, opt_a_new, _ = adam_update(a_grads, params["opt_a"],
                                          params["actor"], lr=lr_a)

    new = {"actor": actor_new,
           "actor_t": soft_update(params["actor_t"], actor_new,
                                  cfg.eps_target),
           "critic": critic_new,
           "critic_t": soft_update(params["critic_t"], critic_new,
                                   cfg.eps_target),
           "opt_a": opt_a_new, "opt_c": opt_c_new}
    td = y_hat - y
    metrics = {"critic_loss": c_loss, "actor_loss": a_loss,
               "q_mean": jnp.mean(y),
               "td_abs_mean": jnp.mean(jnp.abs(td)),
               "td_abs_max": jnp.max(jnp.abs(td)),
               "actor_grad_norm": _tree_l2(a_grads),
               "critic_grad_norm": _tree_l2(c_grads), **chain}
    return new, metrics

# Batched (per-env leading axis) init/update live behind the agent protocol:
# repro.agents.vmap_agent generically lifts any Agent to B stacked learners
# (d3pg_init_batch / d3pg_update_batch remain as shims in repro.agents).


# -- fused B-learner path (DESIGN.md §13) -------------------------------------
#
# Same math and same PRNG streams as jax.vmap of the per-learner functions
# above, but the matmuls of all B learners execute as single batched
# contractions and the B Adam steps as one fused pass.  Per-learner random
# draws stay vmapped (elementwise threefry fuses fine); grad-of-sum over
# per-learner losses equals vmap-of-grad because the stacked parameter
# blocks are independent.  Bit-identity is pinned by tests/test_fused.py.


def actor_act_stacked(actor_params, cfg: D3PGCfg, sched, state, keys):
    """Fused ``actor_act`` over B stacked learners.  state: (B, ..., S);
    keys: (B, 2) — one action key per learner (ignored by the mlp kind,
    exactly like the per-learner path)."""
    if cfg.actor_kind == "diffusion":
        return reverse_sample_actions_stacked(actor_params, sched, state,
                                              keys, cfg.action_dim)
    x = mlp_apply_stacked(actor_params, state, final_act=jnp.tanh)
    return 0.5 * (x + 1.0)


def critic_q_stacked(critic_params, state, action):
    return mlp_apply_stacked(
        critic_params, jnp.concatenate([state, action], axis=-1))[..., 0]


def d3pg_update_stacked(params, cfg: D3PGCfg, sched, batch, keys, *,
                        lr_a=None, lr_c=None, mask=None, diag=False):
    """Fused ``d3pg_update`` over B stacked learners.

    params: stacked (leading ``(B,)`` on every leaf); batch leaves:
    ``(B, n, ...)`` — each learner's own minibatch; keys: ``(B, 2)``;
    ``lr_a``/``lr_c``: python scalars or per-learner ``(B,)`` arrays (the
    population lever); ``mask``: optional ``(B, U)`` per-cell active-user
    mask.  Returns ``(new_params, {"critic_loss": (B,), "actor_loss":
    (B,)})`` exactly like ``jax.vmap(d3pg_update)``.  ``diag=True``
    extends the metrics dict with per-learner ``(B,)`` diagnostics
    (``denoise_mag``: ``(B, L)``), key set per ``d3pg_diag_zero``."""
    if diag:
        return _d3pg_update_stacked_diag(params, cfg, sched, batch, keys,
                                         lr_a=lr_a, lr_c=lr_c, mask=mask)
    lr_a = cfg.lr_actor if lr_a is None else lr_a
    lr_c = cfg.lr_critic if lr_c is None else lr_c
    kk = jax.vmap(jax.random.split)(keys)               # (B, 2, 2)
    k_t, k_pi = kk[:, 0], kk[:, 1]
    U = cfg.action_dim // 2
    # amend_actions is batch-safe: with row-batched inputs the take_along_axis
    # gate and last-axis reductions reproduce the per-row vmap exactly; the
    # per-cell mask broadcasts over the minibatch axis.
    m = None if mask is None else mask[:, None, :]
    amend = lambda raw, req, rho: amend_actions(raw, req, rho, U, mask=m)

    # --- critic (24) ---------------------------------------------------------
    raw1 = actor_act_stacked(params["actor_t"], cfg, sched, batch["s1"], k_t)
    b1, xi1 = amend(raw1, batch["req1"], batch["rho1"])
    a1 = jnp.concatenate([b1, xi1], axis=-1)
    y_hat = batch["r"] + cfg.omega * critic_q_stacked(params["critic_t"],
                                                      batch["s1"], a1)
    y_hat = jax.lax.stop_gradient(y_hat)

    def critic_loss(c):
        y = critic_q_stacked(c, batch["s"], batch["a"])
        per = jnp.mean(0.5 * (y_hat - y) ** 2, axis=-1)          # (B,)
        return jnp.sum(per), per

    (_, c_loss), c_grads = jax.value_and_grad(
        critic_loss, has_aux=True)(params["critic"])
    critic_new, opt_c_new, _ = adam_update_stacked(
        c_grads, params["opt_c"], params["critic"], lr=lr_c)

    # --- actor (26)-(27): maximise Q(s, amend(pi(s))) ------------------------
    def actor_loss(a_params):
        raw = actor_act_stacked(a_params, cfg, sched, batch["s"], k_pi)
        b, xi = amend(raw, batch["req"], batch["rho"])
        act = jnp.concatenate([b, xi], axis=-1)
        per = -jnp.mean(critic_q_stacked(critic_new, batch["s"], act),
                        axis=-1)                                  # (B,)
        return jnp.sum(per), per

    (_, a_loss), a_grads = jax.value_and_grad(
        actor_loss, has_aux=True)(params["actor"])
    actor_new, opt_a_new, _ = adam_update_stacked(
        a_grads, params["opt_a"], params["actor"], lr=lr_a)

    new = {"actor": actor_new,
           "actor_t": soft_update(params["actor_t"], actor_new,
                                  cfg.eps_target),
           "critic": critic_new,
           "critic_t": soft_update(params["critic_t"], critic_new,
                                   cfg.eps_target),
           "opt_a": opt_a_new, "opt_c": opt_c_new}
    return new, {"critic_loss": c_loss, "actor_loss": a_loss}


def _d3pg_update_stacked_diag(params, cfg: D3PGCfg, sched, batch, keys, *,
                              lr_a=None, lr_c=None, mask=None):
    """``d3pg_update_stacked`` with the telemetry tap: same fused update,
    plus per-learner ``(B,)`` diagnostics (``denoise_mag``: ``(B, L)``)."""
    lr_a = cfg.lr_actor if lr_a is None else lr_a
    lr_c = cfg.lr_critic if lr_c is None else lr_c
    kk = jax.vmap(jax.random.split)(keys)               # (B, 2, 2)
    k_t, k_pi = kk[:, 0], kk[:, 1]
    U = cfg.action_dim // 2
    m = None if mask is None else mask[:, None, :]
    amend = lambda raw, req, rho: amend_actions(raw, req, rho, U, mask=m)

    # --- critic (24), tapping the target chain's denoising magnitudes --------
    if cfg.actor_kind == "diffusion":
        raw1, chain = reverse_sample_actions_stacked_stats(
            params["actor_t"], sched, batch["s1"], k_t, cfg.action_dim)
    else:
        raw1 = actor_act_stacked(params["actor_t"], cfg, sched,
                                 batch["s1"], k_t)
        chain = {}
    b1, xi1 = amend(raw1, batch["req1"], batch["rho1"])
    a1 = jnp.concatenate([b1, xi1], axis=-1)
    y_hat = batch["r"] + cfg.omega * critic_q_stacked(params["critic_t"],
                                                      batch["s1"], a1)
    y_hat = jax.lax.stop_gradient(y_hat)

    def critic_loss(c):
        y = critic_q_stacked(c, batch["s"], batch["a"])
        per = jnp.mean(0.5 * (y_hat - y) ** 2, axis=-1)          # (B,)
        return jnp.sum(per), (per, y)

    (_, (c_loss, y)), c_grads = jax.value_and_grad(
        critic_loss, has_aux=True)(params["critic"])
    critic_new, opt_c_new, _ = adam_update_stacked(
        c_grads, params["opt_c"], params["critic"], lr=lr_c)

    # --- actor (26)-(27) -----------------------------------------------------
    def actor_loss(a_params):
        raw = actor_act_stacked(a_params, cfg, sched, batch["s"], k_pi)
        b, xi = amend(raw, batch["req"], batch["rho"])
        act = jnp.concatenate([b, xi], axis=-1)
        per = -jnp.mean(critic_q_stacked(critic_new, batch["s"], act),
                        axis=-1)                                  # (B,)
        return jnp.sum(per), per

    (_, a_loss), a_grads = jax.value_and_grad(
        actor_loss, has_aux=True)(params["actor"])
    actor_new, opt_a_new, _ = adam_update_stacked(
        a_grads, params["opt_a"], params["actor"], lr=lr_a)

    new = {"actor": actor_new,
           "actor_t": soft_update(params["actor_t"], actor_new,
                                  cfg.eps_target),
           "critic": critic_new,
           "critic_t": soft_update(params["critic_t"], critic_new,
                                   cfg.eps_target),
           "opt_a": opt_a_new, "opt_c": opt_c_new}
    td = y_hat - y                                       # (B, n)
    metrics = {"critic_loss": c_loss, "actor_loss": a_loss,
               "q_mean": jnp.mean(y, axis=-1),
               "td_abs_mean": jnp.mean(jnp.abs(td), axis=-1),
               "td_abs_max": jnp.max(jnp.abs(td), axis=-1),
               "actor_grad_norm": _tree_l2_stacked(a_grads),
               "critic_grad_norm": _tree_l2_stacked(c_grads), **chain}
    return new, metrics
