"""Continuous-batching serving engine for CompositeLM models.

Slot-based: a fixed ``max_batch`` of independent sequences share one decode
step (vmapped single-sequence decode, so every slot keeps its own position).
Prefill runs per-request at bucketed lengths (pow-2 padding bounds the
number of compiled variants) and its cache is inserted into the free slot.

This is the substrate the paper assumes exists at the edge: the thing that
actually executes a cached GenAI model for a user request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.lm import (LMCfg, lm_decode, lm_init_cache, lm_prefill)


@dataclasses.dataclass
class ServeCfg:
    max_batch: int = 4
    max_seq: int = 512
    eos_id: int = -1            # -1: never stop early
    pad_id: int = 0


def _bucket(n: int) -> int:
    b = 8
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class _Slot:
    uid: Optional[int] = None
    budget: int = 0
    generated: Optional[list] = None


class Engine:
    def __init__(self, cfg: LMCfg, params, serve_cfg: ServeCfg):
        self.cfg = cfg
        self.params = params
        self.sc = serve_cfg
        B, S = serve_cfg.max_batch, serve_cfg.max_seq
        self.cache = lm_init_cache(cfg, B, S)
        self.pos = np.zeros(B, np.int32)          # next position per slot
        self.slots: List[_Slot] = [_Slot() for _ in range(B)]
        self.last_tok = np.zeros((B, 1), np.int32)

        cache_axes = jax.tree.map(lambda _: 1, self.cache)

        def _decode1(params, tok, cache, pos):
            # tok: (1,) -> (1,1); vmap strips the batch axis from the cache,
            # so re-insert a singleton batch dim for the model and squeeze
            # it back out for the vmapped out_axes.
            cache = jax.tree.map(lambda c: jnp.expand_dims(c, 1), cache)
            logits, cache = lm_decode(params, cfg, tok[None], cache, pos)
            cache = jax.tree.map(lambda c: jnp.squeeze(c, 1), cache)
            return logits, cache

        self._vdecode = jax.jit(jax.vmap(
            _decode1, in_axes=(None, 0, cache_axes, 0),
            out_axes=(0, cache_axes)))

        self._prefill = jax.jit(
            lambda params, toks, cache: lm_prefill(params, cfg, toks, cache))

        self._sub_cache = jax.jit(
            lambda cache, i: jax.tree.map(
                lambda c: jax.lax.dynamic_slice_in_dim(c, i, 1, axis=1),
                cache))
        self._set_cache = jax.jit(
            lambda cache, sub, i: jax.tree.map(
                lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                    c, s.astype(c.dtype), i, axis=1), cache, sub))

    # -- admission -------------------------------------------------------------

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.uid is None:
                return i
        return None

    def admit(self, uid: int, prompt: np.ndarray, max_new_tokens: int) -> int:
        """Prefill ``prompt`` into a free slot; returns the slot index."""
        slot = self.free_slot()
        assert slot is not None, "no free slot"
        L = int(prompt.shape[-1])
        Lb = min(_bucket(L), self.sc.max_seq)
        toks = np.full((1, Lb), self.sc.pad_id, np.int32)
        toks[0, :L] = prompt
        sub = self._sub_cache(self.cache, slot)
        logits, sub = self._prefill(self.params, jnp.asarray(toks), sub)
        self.cache = self._set_cache(self.cache, sub, slot)
        nxt = int(jnp.argmax(logits[0, -1]))
        self.pos[slot] = Lb
        self.last_tok[slot, 0] = nxt
        self.slots[slot] = _Slot(uid=uid, budget=max_new_tokens,
                                 generated=[nxt])
        return slot

    # -- decode ---------------------------------------------------------------

    def active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.uid is not None]

    def step(self):
        """One continuous-batching decode step over all slots."""
        logits, self.cache = self._vdecode(
            self.params, jnp.asarray(self.last_tok),
            self.cache, jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0, -1, :], axis=-1),
                         np.int32)
        finished = []
        for i, s in enumerate(self.slots):
            if s.uid is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            s.generated.append(tok)
            s.budget -= 1
            if (s.budget <= 0 or tok == self.sc.eos_id
                    or self.pos[i] >= self.sc.max_seq - 1):
                finished.append((s.uid, list(s.generated)))
                self.slots[i] = _Slot()
            else:
                self.last_tok[i, 0] = tok
        return finished

    # -- convenience ------------------------------------------------------------

    def run(self, requests, *, on_finish: Optional[Callable] = None):
        """Serve a list of (uid, prompt ndarray, max_new_tokens) with
        continuous batching.  Returns {uid: generated tokens} and timing."""
        t0 = time.perf_counter()
        pending = list(requests)
        done = {}
        steps = 0
        while pending or self.active():
            while pending and self.free_slot() is not None:
                uid, prompt, mnt = pending.pop(0)
                self.admit(uid, prompt, mnt)
            for uid, toks in self.step():
                done[uid] = toks
                if on_finish:
                    on_finish(uid, toks)
            steps += 1
        return done, {"wall_s": time.perf_counter() - t0,
                      "decode_steps": steps}
