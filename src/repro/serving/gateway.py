"""Edge AIGC gateway — the paper's control plane wired to *real* execution.

The paper models the edge server analytically (Eqs. 7-8).  This gateway goes
beyond: it maintains an actual model catalogue (instantiated JAX models —
diffusion image generators and/or CompositeLM engines), applies the DDQN
caching vector rho by loading/evicting real parameter pytrees against a byte
budget, and executes each slot's requests under the D3PG allocation
(xi -> denoising-step / token budget), reporting both the *modeled* quality/
delay (the paper's fitted curves) and the *measured* wall-clock on this host.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quality import gen_delay, tv_quality
from repro.diffusion import (denoiser_init, make_schedule, reverse_sample)


@dataclasses.dataclass
class CatalogEntry:
    model_id: int
    name: str
    kind: str                     # "diffusion" | "lm"
    size_gb: float
    builder: Callable[[], object]  # -> params (diffusion) or Engine (lm)
    # fitted-curve parameters (paper Sec. 7.1 ranges)
    a1: float = 60.0
    a2: float = 110.0
    a3: float = 170.0
    a4: float = 28.0
    b1: float = 0.18
    b2: float = 5.74


@dataclasses.dataclass
class ServedResult:
    model_id: int
    cached: bool
    steps: int
    modeled_quality: float
    modeled_delay: float
    measured_wall_s: float
    output_shape: tuple


class EdgeGateway:
    def __init__(self, catalogue: List[CatalogEntry], capacity_gb: float,
                 *, image_dim: int = 256, total_steps: int = 1000):
        self.catalogue: Dict[int, CatalogEntry] = {
            e.model_id: e for e in catalogue}
        self.capacity_gb = capacity_gb
        self.loaded: Dict[int, object] = {}
        self.image_dim = image_dim
        self.total_steps = total_steps
        self._samplers: Dict[int, Callable] = {}

    # -- caching (long timescale) -----------------------------------------------

    def used_gb(self) -> float:
        return sum(self.catalogue[m].size_gb for m in self.loaded)

    def apply_caching(self, rho: np.ndarray) -> Dict[str, float]:
        """Load/evict real model instances to match the caching vector.
        Infeasible rho (storage overflow) is truncated in id order — the
        physical analogue of the paper's soft penalty Xi."""
        want = [m for m, r in enumerate(np.asarray(rho)) if r > 0.5
                and m in self.catalogue]
        # evict
        for m in list(self.loaded):
            if m not in want:
                del self.loaded[m]
                self._samplers.pop(m, None)
        # load in id order until capacity
        t0 = time.perf_counter()
        for m in want:
            if m in self.loaded:
                continue
            e = self.catalogue[m]
            if self.used_gb() + e.size_gb > self.capacity_gb:
                continue
            self.loaded[m] = e.builder()
        return {"load_s": time.perf_counter() - t0,
                "used_gb": self.used_gb(),
                "n_loaded": float(len(self.loaded))}

    # -- execution (short timescale) ---------------------------------------------

    def _diffusion_sampler(self, m: int):
        """Jitted L-step image sampler for model m (cached per step count)."""
        if m not in self._samplers:
            params = self.loaded[m]

            def sample(key, steps):
                sched = make_schedule(int(steps), kind="linear")
                state = jnp.zeros((1,))  # unconditional
                return reverse_sample(params, sched, state, key,
                                      self.image_dim)

            self._samplers[m] = sample
        return self._samplers[m]

    def serve_request(self, model_id: int, xi: float, key,
                      prompt: Optional[np.ndarray] = None) -> ServedResult:
        """Execute one request under compute share xi (Eq. 7-8 knob)."""
        e = self.catalogue[model_id]
        cached = model_id in self.loaded
        steps = int(max(1, round(float(xi) * self.total_steps)))
        if not cached:
            # cloud path: modeled only (paper Sec. 3.4)
            return ServedResult(
                model_id, False, int(e.a3),
                modeled_quality=float(e.a4),
                modeled_delay=float(e.b1 * e.a3 + e.b2),
                measured_wall_s=0.0, output_shape=())
        t0 = time.perf_counter()
        if e.kind == "diffusion":
            out = self._diffusion_sampler(model_id)(key, steps)
            out.block_until_ready()
            shape = tuple(out.shape)
        else:  # lm: xi -> decode token budget
            engine = self.loaded[model_id]
            prompt = (np.arange(8, dtype=np.int32) % engine.cfg.vocab
                      if prompt is None else prompt)
            done, _ = engine.run([(0, prompt, max(1, steps // 16))])
            shape = (len(done[0]),)
        wall = time.perf_counter() - t0
        q = float(tv_quality(jnp.float32(steps), e.a1, e.a2, e.a3, e.a4))
        d = float(gen_delay(jnp.float32(steps), e.b1, e.b2))
        return ServedResult(model_id, True, steps, q, d, wall, shape)

    def serve_slot(self, requests: List[int], xi: np.ndarray, key
                   ) -> List[ServedResult]:
        """requests: per-user model ids; xi: per-user compute shares."""
        out = []
        for u, (m, x) in enumerate(zip(requests, np.asarray(xi))):
            out.append(self.serve_request(int(m), float(x),
                                          jax.random.fold_in(key, u)))
        return out


def toy_diffusion_builder(seed: int, image_dim: int = 256):
    """A small unconditional DDPM denoiser standing in for RePaint."""
    def build():
        return denoiser_init(jax.random.PRNGKey(seed), 1, image_dim,
                             hidden=128, n_layers=3)
    return build
