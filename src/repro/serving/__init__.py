from .engine import Engine, ServeCfg  # noqa: F401
from .gateway import CatalogEntry, EdgeGateway  # noqa: F401
