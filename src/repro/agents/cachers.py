"""Long-timescale (per-frame) caching agents behind the protocol.

Cacher ``act`` returns ``(a_int, rho)`` — the raw integer action (what the
DDQN frame transition stores) and the amended caching vector.  As with the
allocators, closures call the numeric cores (``repro.core.ddqn`` /
``repro.core.baselines``) verbatim.

Beyond the paper's ddqn/static/random triple, :func:`classical_cacher`
exposes the adaptive cache-hierarchy baselines of DESIGN.md §14
(LRU/LFU/ghost-LRU/ARC from ``repro.core.cache_policies``) as STATEFUL
non-learned agents: ``act`` just snapshots the resident set into the
frame's caching vector, and the optional ``step_frame`` closure replays
the frame's request stream through the array state machine afterwards —
so the cache serving frame ``t`` reflects exactly the requests of frames
``< t`` (same causality as the DDQN's popularity-state conditioning).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.baselines import random_cache, static_popular_cache
from repro.core.cache_policies import (CACHE_POLICIES, cache_access,
                                       cache_rho, cache_state_init,
                                       quantize_capacity, quantize_sizes)
from repro.core.ddqn import (DDQNCfg, amend_caching, ddqn_act,
                             ddqn_act_stacked, ddqn_diag_zero, ddqn_init,
                             ddqn_update, ddqn_update_stacked)
from repro.core.env import EnvCfg

from .base import Agent, no_update


def ddqn_cacher(dq: DDQNCfg, env_cfg: EnvCfg, diag: bool = False) -> Agent:
    """The paper's DDQN cacher over the 2^M caching actions.

    ``act`` is batch-transparent in the epsilon-greedy draw (one key drives
    a ``(B,)`` batch of popularity states, as the legacy lockstep frame
    step did); the amender is vmapped only when the model zoo carries a
    cell axis.  ``diag=True`` builds the telemetry variant (DESIGN.md
    §15): ``update`` returns the extended diagnostics dict and
    ``diag_zero`` is provided for the driver's in-scan tap."""

    def act(state, obs, key, step):
        a_int = ddqn_act(state, dq, obs.gamma_idx, key, step["eps"])
        rho = amend_caching(a_int, dq, obs.models.c, env_cfg.C)
        return a_int, rho

    def batch_act(state, obs, key, step):
        a_int = ddqn_act(state, dq, obs.gamma_idx, key, step["eps"])
        rho = jax.vmap(lambda a, c: amend_caching(a, dq, c, env_cfg.C))(
            a_int, obs.models.c)
        return a_int, rho

    def update(state, batch, key):
        data = {k: v for k, v in batch.items() if k != "lr"}
        new, m = ddqn_update(state, dq, data, lr=batch.get("lr"), diag=diag)
        return new, (m if diag else {"loss": m})

    def greedy(policy, obs, key):
        a_int = ddqn_act(policy["ddqn"], dq, obs.gamma_idx, key, 0.0)
        return amend_caching(a_int, dq, obs.models.c, env_cfg.C)

    # -- fused B-learner closures (DESIGN.md §13): the Q-net forward of all
    # B cells runs as one batched contraction; the amender stays vmapped
    # (per-cell model zoos, and the feasible amender is single-env only).

    def act_stacked(state, obs, keys, step):
        a_int = ddqn_act_stacked(state, dq, obs.gamma_idx, keys, step["eps"])
        rho = jax.vmap(lambda a, c: amend_caching(a, dq, c, env_cfg.C))(
            a_int, obs.models.c)
        return a_int, rho

    def update_stacked(state, batch, keys):
        data = {k: v for k, v in batch.items() if k != "lr"}
        new, m = ddqn_update_stacked(state, dq, data, lr=batch.get("lr"),
                                     diag=diag)
        return new, (m if diag else {"loss": m})

    return Agent(name="ddqn", learns=True,
                 init=lambda key: ddqn_init(key, dq),
                 act=act, update=update,
                 export=lambda state: {"ddqn": {"q": state["q"]}},
                 greedy=greedy, batch_act=batch_act,
                 act_stacked=act_stacked, update_stacked=update_stacked,
                 diag_zero=(lambda: ddqn_diag_zero(dq)) if diag else None)


def static_cacher(env_cfg: EnvCfg) -> Agent:
    """SCHRS static caching: most-popular models greedily to capacity."""

    def act(state, obs, key, step):
        a_int = jnp.int32(0)
        return a_int, static_popular_cache(obs.models, env_cfg)

    def batch_act(state, obs, key, step):
        B = obs.gamma_idx.shape[0]
        rho = jax.vmap(lambda m: static_popular_cache(m, env_cfg))(obs.models)
        return jnp.zeros((B,), jnp.int32), rho

    return Agent(name="static", learns=False,
                 init=lambda key: {}, act=act, update=no_update,
                 export=lambda state: {},
                 greedy=lambda policy, obs, key: static_popular_cache(
                     obs.models, env_cfg),
                 batch_act=batch_act)


def random_cacher(env_cfg: EnvCfg) -> Agent:
    """RCARS random caching: random-order greedy fill, one key per cell in
    lockstep mode (the legacy ``random_cache_batch`` key derivation)."""

    def act(state, obs, key, step):
        a_int = jnp.int32(0)
        return a_int, random_cache(key, obs.models, env_cfg)

    def batch_act(state, obs, key, step):
        B = obs.gamma_idx.shape[0]
        rho = jax.vmap(lambda k, m: random_cache(k, m, env_cfg))(
            jax.random.split(key, B), obs.models)
        return jnp.zeros((B,), jnp.int32), rho

    return Agent(name="random", learns=False,
                 init=lambda key: {}, act=act, update=no_update,
                 export=lambda state: {},
                 greedy=lambda policy, obs, key: random_cache(
                     key, obs.models, env_cfg),
                 batch_act=batch_act)


def classical_cacher(kind: str, env_cfg: EnvCfg) -> Agent:
    """A classical cache-hierarchy baseline (DESIGN.md §14) as an Agent.

    The agent's state is the ``repro.core.cache_policies`` array state
    machine (the driver threads it through the ``"cache"`` TrainState
    slot).  ``act`` is a pure snapshot — it returns the resident set as
    the frame's caching vector and is batch-transparent (every state op
    is elementwise over the trailing ``(M,)`` axis).  ``step_frame``
    replays the frame's ``(K, U)`` request stream through the policy's
    access function via one ``lax.scan`` (row-major: slot 0's users
    first, users in index order within a slot — the tie-break order the
    Python references in ``tests/_cache_refs.py`` mirror).  Inactive
    users (``mask``) are replayed as no-op accesses."""
    if kind not in CACHE_POLICIES:
        raise ValueError(f"unknown cache policy {kind!r}; expected one of "
                         f"{CACHE_POLICIES}")
    cap_units = quantize_capacity(env_cfg.C)

    def act(state, obs, key, step):
        a_int = jnp.zeros(jnp.shape(obs.gamma_idx), jnp.int32)
        return a_int, cache_rho(state)

    def step_frame(state, reqs, models, mask):
        c_units = quantize_sizes(models.c)
        stream = reqs.reshape(-1)                       # (K*U,) row-major
        if mask is None:
            valid = jnp.ones(stream.shape, jnp.bool_)
        else:
            valid = jnp.tile(mask.astype(jnp.bool_), reqs.shape[0])

        def one(st, mx):
            m, v = mx
            st, _ = cache_access(kind, st, m, c_units, cap_units, v)
            return st, None

        state, _ = jax.lax.scan(one, state, (stream, valid))
        return state

    return Agent(name=kind, learns=False,
                 init=lambda key: cache_state_init(env_cfg.M),
                 act=act, update=no_update,
                 export=lambda state: {"cache": {"rho": cache_rho(state)}},
                 greedy=lambda policy, obs, key: policy["cache"]["rho"],
                 step_frame=step_frame)


CACHERS = ("ddqn", "static", "random") + CACHE_POLICIES


def make_cacher(kind: str, dq: DDQNCfg, env_cfg: EnvCfg,
                diag: bool = False) -> Agent:
    """Dispatch a long-timescale cacher name to its Agent bundle — the
    only place cacher kinds are branched on (DESIGN.md §12).  ``diag``
    builds the DDQN cacher with telemetry diagnostics (no-op for the
    non-learned baselines)."""
    if kind == "ddqn":
        return ddqn_cacher(dq, env_cfg, diag=diag)
    if kind == "static":
        return static_cacher(env_cfg)
    if kind == "random":
        return random_cacher(env_cfg)
    if kind in CACHE_POLICIES:
        return classical_cacher(kind, env_cfg)
    raise ValueError(f"unknown cacher {kind!r}; expected one of {CACHERS}")
