"""Agent protocol package (DESIGN.md §12).

``Agent`` is the one learner API the two-timescale driver is written
against; ``make_allocator`` / ``make_cacher`` dispatch a method name to its
protocol bundle (the only places agent kinds are branched on);
``vmap_agent`` is the single generic batching wrapper.

Import discipline: this package's submodules import only ``repro.core``
*submodules* (``d3pg``/``ddqn``/``baselines``/``env``), never the
``repro.core`` package surface, and ``repro.core.t2drl`` imports only
*submodules* of this package — so either package may be imported first
without a cycle.
"""
from .base import (Agent, FrameObs, SlotObs, no_update,  # noqa: F401
                   vmap_agent)
from .allocators import (ALLOCATORS, d3pg_allocator, make_allocator,  # noqa: F401
                         rcars_allocator, schrs_allocator)
from .cachers import (CACHERS, classical_cacher, ddqn_cacher,  # noqa: F401
                      make_cacher, random_cacher, static_cacher)
from .compat import (d3pg_init_batch, d3pg_update_batch,  # noqa: F401
                     ddqn_init_batch, ddqn_update_batch)
