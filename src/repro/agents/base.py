"""The agent protocol (DESIGN.md §12): one learner API for every method.

An :class:`Agent` is a NamedTuple of pure closures over a frozen config —
``init(key) -> state``, ``act(state, obs, keys, step) -> action``,
``update(state, batch, key) -> (state, metrics)`` — plus the inference-side
closures the serving stack needs (``export``, ``greedy``).  The two-timescale
driver in ``repro.core.t2drl`` is written against this protocol only; which
paper method runs (D3PG/DDPG/SCHRS/RCARS allocators, DDQN/static/random
cachers) is decided once, in the factory functions of
``repro.agents.allocators`` / ``repro.agents.cachers``.

Batching is obtained once, generically, via :func:`vmap_agent` (B independent
learners as one stacked state pytree) instead of per-module ``*_batch``
duplicates.  Lockstep vector-env rollouts additionally use ``batch_act``:
``None`` declares ``act`` batch-transparent (one PRNG key drives the whole
batch — e.g. a single actor network applied to ``(B, S)`` observations),
while agents whose action sampler is inherently per-env (the SCHRS GA, the
random cacher) supply an explicit lockstep ``batch_act`` that splits the key
per cell.

Conventions (DESIGN.md §12):

- ``obs`` is a :class:`SlotObs` for allocators (per-slot agents) and a
  :class:`FrameObs` for cachers (per-frame agents).
- ``keys`` for ``act`` is whatever key material the driver hands the agent —
  a ``(2, 2)`` stacked pair for slot allocators (actor chain + exploration
  noise, preserving the episode PRNG stream exactly), a single key for
  cachers.  Agents must not re-split driver keys.
- ``step`` is a dict of per-step schedule scalars (``eps``, ``sigma``).
- ``batch`` for ``update`` is the sampled replay minibatch; the reserved
  keys ``mask`` / ``lr_actor`` / ``lr_critic`` carry per-call auxiliaries
  (active-user masks, schedule-driven learning rates) and are stripped
  before the minibatch reaches the numeric update.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax


class SlotObs(NamedTuple):
    """What a per-slot allocator may condition on.

    ``s`` is the Eq. (21) observation vector (``(..., S)``); ``env`` the raw
    :class:`~repro.core.env.EnvState` (the amenders need ``req``/``rho``,
    the GA baseline scores candidate allocations against the full state);
    ``models`` the cell's model zoo; ``mask`` an optional ``(..., U)``
    active-user mask."""
    s: Any
    env: Any
    models: Any
    mask: Any = None


class FrameObs(NamedTuple):
    """What a per-frame cacher may condition on: the popularity state index
    ``gamma_idx`` (the paper's DDQN state) and the model zoo (the amenders
    need per-model storage sizes)."""
    gamma_idx: Any
    models: Any


class Agent(NamedTuple):
    """A learner as a bundle of pure closures (DESIGN.md §12).

    Attributes
    ----------
    name : str
        Method name (``"d3pg"``, ``"ddqn"``, ...), for error messages and
        checkpoint metadata.
    learns : bool
        Whether the driver should store transitions and call ``update``.
        Static — python-level branching on it specializes the compiled
        episode program per method.
    init : callable
        ``init(key) -> state`` — fresh parameter/optimizer pytree.
    act : callable
        ``act(state, obs, keys, step) -> action``.  Slot allocators return
        the amended ``(b, xi)``; frame cachers return ``(a_int, rho)``.
    update : callable
        ``update(state, batch, key) -> (state, metrics)``.  ``batch`` may
        carry the reserved auxiliaries (see module docstring).
    export : callable
        ``export(state) -> dict`` — the inference-only parameter slice
        (empty for non-learned agents), the unit ``repro.checkpoint`` saves
        and the fleet twin restores.
    greedy : callable
        ``greedy(policy, obs, key) -> action`` — inference from an
        ``export``-ed policy slice at zero exploration.
    batch_act : callable, optional
        Lockstep vector-env action sampler (``None`` = ``act`` is
        batch-transparent; see module docstring).
    act_stacked : callable, optional
        Fused B-learner ``act`` (DESIGN.md §13): same signature as the
        vmapped ``act`` of :func:`vmap_agent` — stacked state (leading
        ``(B,)`` on every leaf), per-cell obs/keys — but implemented as
        single batched contractions instead of B per-learner programs.
        ``step`` values may additionally be per-learner ``(B,)`` arrays
        (the population lever).  Must be bit-identical to the vmapped
        ``act`` on the same inputs.  ``None`` = no fused path; the vmap
        fallback is used.
    update_stacked : callable, optional
        Fused B-learner ``update``; same contract as ``act_stacked``.
    step_frame : callable, optional
        Per-frame deterministic state advance for STATEFUL non-learned
        cachers (the classical cache hierarchy, DESIGN.md §14):
        ``step_frame(state, reqs, models, mask) -> state`` replays the
        frame's ``(K, U)`` request stream through the cacher's internal
        state machine after the frame's slots have been served.  ``None``
        (every learned/stateless agent) keeps the driver's compiled
        program byte-identical to the pre-§14 one; the driver branches on
        ``step_frame is not None`` python-statically.
    diag_zero : callable, optional
        Telemetry (DESIGN.md §15): ``diag_zero() -> dict`` — a zeros
        pytree structurally matching the metrics this agent's ``update``
        returns when built with diagnostics on.  The driver's in-scan
        taps use it as the skipped-update branch of the ``lax.cond``
        around ``update`` (warmup / buffer-fill gating needs both
        branches to return the same pytree).  ``None`` (the default, and
        every agent built with ``diag=False``) declares no tap; the
        driver then compiles the exact pre-telemetry program.
    """
    name: str
    learns: bool
    init: Callable
    act: Callable
    update: Callable
    export: Callable
    greedy: Callable
    batch_act: Optional[Callable] = None
    act_stacked: Optional[Callable] = None
    update_stacked: Optional[Callable] = None
    step_frame: Optional[Callable] = None
    diag_zero: Optional[Callable] = None


def no_update(state, batch, key):
    """Shared ``update`` for non-learned agents: identity, no metrics."""
    return state, {}


def vmap_agent(agent: Agent, impl: str = "fused") -> Agent:
    """Lift an agent to B independent learners as one stacked pytree.

    The returned agent's ``init`` takes ``(B, 2)`` stacked PRNG keys and
    returns a state whose every leaf carries a leading ``(B,)`` axis;
    ``act``/``update`` map per-cell states to per-cell observations /
    minibatches with per-cell keys.  This is the single generic batching
    wrapper that replaces the former ``d3pg_*_batch`` / ``ddqn_*_batch``
    duplicates (DESIGN.md §12).

    ``impl`` selects how the stacked learners execute (DESIGN.md §13):

    - ``"fused"`` (default): use the agent's hand-fused ``act_stacked`` /
      ``update_stacked`` closures where provided — all B learners advance
      through single batched contractions and one fused optimizer pass —
      falling back to ``jax.vmap`` per closure where not.  Per-``step``
      schedule values may be per-learner ``(B,)`` arrays (population
      training).
    - ``"vmap"``: plain ``jax.vmap`` of every closure — the bit-identity
      reference the fused path is pinned against (``tests/test_fused.py``).
    """
    if impl not in ("fused", "vmap"):
        raise ValueError(f"vmap_agent: unknown impl {impl!r}; "
                         f"expected 'fused' or 'vmap'")
    fused = impl == "fused"
    act = agent.act_stacked if fused and agent.act_stacked is not None \
        else jax.vmap(agent.act, in_axes=(0, 0, 0, None))
    update = agent.update_stacked \
        if fused and agent.update_stacked is not None \
        else jax.vmap(agent.update, in_axes=(0, 0, 0))
    return agent._replace(
        init=jax.vmap(agent.init),
        act=act,
        update=update,
        batch_act=None,
        act_stacked=None,
        update_stacked=None,
        # step_frame stays unbatched on the factory agent — the episode
        # cores vmap it explicitly over (state, reqs, models, mask), which
        # this wrapper cannot know the in_axes of
        step_frame=None,
    )
