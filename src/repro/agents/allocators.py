"""Short-timescale (per-slot) allocation agents behind the protocol.

Factories return :class:`~repro.agents.base.Agent` bundles whose closures
call the numeric cores in ``repro.core.d3pg`` / ``repro.core.baselines``
verbatim — the protocol adds dispatch, not arithmetic.  Each agent's
init/act/update is bit-identical to the legacy per-method functions on the
same inputs (pinned by ``tests/test_agents.py``); driver-level semantics
that changed alongside the refactor (per-frame replay write batching) are
documented in DESIGN.md §12.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.baselines import GACfg, ga_allocate, rcars_allocate
from repro.core.d3pg import (D3PGCfg, actor_act, actor_act_stacked,
                             amend_actions, d3pg_diag_zero, d3pg_init,
                             d3pg_update, d3pg_update_stacked,
                             make_actor_schedule)
from repro.core.env import EnvCfg

from .base import Agent, no_update

_UPDATE_AUX = ("mask", "lr_actor", "lr_critic")


def d3pg_allocator(d3: D3PGCfg, sched=None, diag: bool = False) -> Agent:
    """The paper's D3PG allocator (``actor_kind="mlp"`` recovers DDPG).

    ``act`` consumes a ``(2, 2)`` stacked key pair — ``keys[0]`` drives the
    diffusion reverse chain, ``keys[1]`` the Gaussian exploration noise
    (``step["sigma"]``) — exactly the two driver-split keys the legacy slot
    step used, so the episode PRNG stream is unchanged.  ``act`` is
    batch-transparent: one key pair serves a whole ``(B, S)`` lockstep
    batch (``batch_act=None``).  ``sched`` overrides the actor's diffusion
    schedule (default: derived from ``d3``).  ``diag=True`` builds the
    telemetry variant (DESIGN.md §15): ``update`` returns the extended
    diagnostics dict and ``diag_zero`` is provided for the driver's
    in-scan tap."""
    sched = make_actor_schedule(d3) if sched is None else sched
    U = d3.action_dim // 2

    def act(state, obs, keys, step):
        raw = actor_act(state["actor"], d3, sched, obs.s, keys[0])
        raw = jnp.clip(
            raw + step["sigma"] * jax.random.normal(keys[1], raw.shape),
            0.0, 1.0)
        return amend_actions(raw, obs.env.req, obs.env.rho, U, mask=obs.mask)

    def update(state, batch, key):
        data = {k: v for k, v in batch.items() if k not in _UPDATE_AUX}
        return d3pg_update(state, d3, sched, data, key,
                           mask=batch.get("mask"),
                           lr_a=batch.get("lr_actor"),
                           lr_c=batch.get("lr_critic"), diag=diag)

    def greedy(policy, obs, key):
        raw = actor_act(policy["actor"], d3, sched, obs.s, key)
        return amend_actions(raw, obs.env.req, obs.env.rho, U, mask=obs.mask)

    # -- fused B-learner closures (DESIGN.md §13): same math / PRNG streams
    # as jax.vmap of act/update above, executed as batched contractions.

    def act_stacked(state, obs, keys, step):
        # keys: (B, 2, 2) — per-cell (chain, noise) pairs
        raw = actor_act_stacked(state["actor"], d3, sched, obs.s, keys[:, 0])
        noise = jax.vmap(
            lambda k, r: jax.random.normal(k, r.shape))(keys[:, 1], raw)
        sigma = jnp.asarray(step["sigma"], jnp.float32)
        if sigma.ndim:                       # per-learner (B,) population lever
            sigma = sigma.reshape(sigma.shape + (1,) * (raw.ndim - 1))
        raw = jnp.clip(raw + sigma * noise, 0.0, 1.0)
        return amend_actions(raw, obs.env.req, obs.env.rho, U, mask=obs.mask)

    def update_stacked(state, batch, keys):
        data = {k: v for k, v in batch.items() if k not in _UPDATE_AUX}
        return d3pg_update_stacked(state, d3, sched, data, keys,
                                   mask=batch.get("mask"),
                                   lr_a=batch.get("lr_actor"),
                                   lr_c=batch.get("lr_critic"), diag=diag)

    return Agent(name="d3pg" if d3.actor_kind == "diffusion" else "ddpg",
                 learns=True,
                 init=lambda key: d3pg_init(key, d3),
                 act=act, update=update,
                 export=lambda state: {"actor": state["actor"]},
                 greedy=greedy,
                 act_stacked=act_stacked, update_stacked=update_stacked,
                 diag_zero=(lambda: d3pg_diag_zero(d3)) if diag else None)


def schrs_allocator(env_cfg: EnvCfg, ga: GACfg) -> Agent:
    """SCHRS per-slot genetic algorithm (no learned state).

    The GA is inherently per-env (one population per cell), so the lockstep
    ``batch_act`` splits the chain key per cell — the same
    ``split(keys[0], B)`` the legacy shared-mode slot step used."""

    def act(state, obs, keys, step):
        return ga_allocate(keys[0], obs.env, env_cfg, obs.models, ga)

    def batch_act(state, obs, keys, step):
        B = obs.env.gamma_idx.shape[0]
        return jax.vmap(
            lambda k, e, m: ga_allocate(k, e, env_cfg, m, ga))(
                jax.random.split(keys[0], B), obs.env, obs.models)

    return Agent(name="schrs", learns=False,
                 init=lambda key: {}, act=act, update=no_update,
                 export=lambda state: {},
                 greedy=lambda policy, obs, key: ga_allocate(
                     key, obs.env, env_cfg, obs.models, ga),
                 batch_act=batch_act)


def rcars_allocator(env_cfg: EnvCfg) -> Agent:
    """RCARS equal-split allocation (deterministic, keyless)."""

    def act(state, obs, keys, step):
        return rcars_allocate(obs.env, env_cfg)

    def batch_act(state, obs, keys, step):
        return jax.vmap(lambda e: rcars_allocate(e, env_cfg))(obs.env)

    return Agent(name="rcars", learns=False,
                 init=lambda key: {}, act=act, update=no_update,
                 export=lambda state: {},
                 greedy=lambda policy, obs, key: rcars_allocate(
                     obs.env, env_cfg),
                 batch_act=batch_act)


ALLOCATORS = ("d3pg", "ddpg", "schrs", "rcars")


def make_allocator(kind: str, env_cfg: EnvCfg, d3: D3PGCfg,
                   ga: GACfg, diag: bool = False) -> Agent:
    """Dispatch a short-timescale allocator name to its Agent bundle — the
    only place allocator kinds are branched on (DESIGN.md §12).  ``diag``
    builds the learned allocator with telemetry diagnostics (no-op for
    the non-learned baselines)."""
    if kind in ("d3pg", "ddpg"):
        return d3pg_allocator(d3, diag=diag)
    if kind == "schrs":
        return schrs_allocator(env_cfg, ga)
    if kind == "rcars":
        return rcars_allocator(env_cfg)
    raise ValueError(f"unknown allocator {kind!r}; expected one of "
                     f"{ALLOCATORS}")
