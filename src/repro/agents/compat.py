"""Legacy ``*_batch`` helpers as thin shims over :func:`vmap_agent`.

These names predate the agent protocol (they were bespoke duplicates in
``core/d3pg.py`` / ``core/ddqn.py``); they are re-exported unchanged
through ``repro.core`` for API stability but now all route through the one
generic batching wrapper (DESIGN.md §12).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.d3pg import D3PGCfg
from repro.core.ddqn import DDQNCfg
from repro.core.env import EnvCfg

from .allocators import d3pg_allocator
from .base import vmap_agent
from .cachers import ddqn_cacher


def _broadcast_aux(aux, B):
    """Broadcast shared per-call auxiliaries (masks, lr scalars) to a
    leading (B,) cell axis for the vmapped protocol update."""
    return {k: jnp.broadcast_to(jnp.asarray(v), (B,) + jnp.shape(v))
            for k, v in aux.items() if v is not None}


def d3pg_init_batch(keys, cfg: D3PGCfg):
    """B independent actor/critic/optimizer stacks; keys: (B, 2)."""
    return vmap_agent(d3pg_allocator(cfg)).init(keys)


def d3pg_update_batch(params, cfg: D3PGCfg, sched, batch, keys, *,
                      lr_a=None, lr_c=None, mask=None):
    """One minibatch step per env in a single compiled call.  ``params`` and
    ``batch`` carry a leading (B,) axis; keys: (B, 2).  ``sched`` is the
    actor's diffusion schedule, honored as given (as in the legacy
    implementation).  Returns (params, losses) with per-env losses of
    shape (B,)."""
    B = keys.shape[0]
    aux = _broadcast_aux({"lr_actor": lr_a, "lr_critic": lr_c, "mask": mask},
                         B)
    return vmap_agent(d3pg_allocator(cfg, sched)).update(
        params, {**batch, **aux}, keys)


def ddqn_init_batch(keys, cfg: DDQNCfg):
    """B independent Q/target/optimizer stacks; keys: (B, 2)."""
    return vmap_agent(ddqn_cacher(cfg, EnvCfg(M=cfg.M))).init(keys)


def ddqn_update_batch(params, cfg: DDQNCfg, batch, *, lr=None):
    """One minibatch step per env; ``params``/``batch`` carry a leading
    (B,) axis.  Returns (params, per-env losses of shape (B,))."""
    B = jax.tree.leaves(batch)[0].shape[0]
    aux = _broadcast_aux({"lr": lr}, B)
    keys = jnp.zeros((B, 2), jnp.uint32)   # ddqn_update is keyless
    new, metrics = vmap_agent(ddqn_cacher(cfg, EnvCfg(M=cfg.M))).update(
        params, {**batch, **aux}, keys)
    return new, metrics["loss"]
