"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), TPU v5e-class constants:

  compute    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips × 819 GB/s HBM)
  collective = collective_bytes / (chips × 50 GB/s ICI link)

``cost_analysis()`` on the compiled (SPMD-partitioned) module reports
*per-device* flops/bytes, so terms are computed per chip directly —
equivalent to the total/(chips×peak) formulation.  Collective bytes are not
in cost_analysis: we parse the partitioned HLO text and sum the output-shape
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, scaled by the standard per-algorithm wire factors.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# approximate wire-bytes factor per algorithm (ring), relative to the
# parsed output-shape bytes
_WIRE_FACTOR = {
    "all-gather": 1.0,        # each device receives (n-1)/n of the output
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum output-shape bytes of every collective in (partitioned) HLO text,
    keyed by op kind; 'total' applies the wire factors."""
    out: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    count: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, _, rhs = stripped.partition("=")
        kind = None
        for op in COLLECTIVE_OPS:
            # match the op as the instruction (e.g. " all-gather(", incl.
            # variants like all-gather-start)
            if re.search(rf"\b{op}(-start)?\(", rhs):
                kind = op
                break
        if kind is None:
            continue
        # output shape(s): first shape token(s) on the rhs before the op name
        head = rhs.split(kind)[0]
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        out[kind] += nbytes
        count[kind] += 1
    out["total"] = sum(out[k] * _WIRE_FACTOR[k] for k in COLLECTIVE_OPS)
    out["counts"] = count  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: Optional[float] = None
    useful_ratio: Optional[float] = None

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline(cost: dict, coll: Dict[str, float], *, chips: int,
             model_flops_total: Optional[float] = None) -> Roofline:
    """cost: compiled.cost_analysis() of the PARTITIONED module (per-chip)."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cb = float(coll.get("total", 0.0))
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = cb / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_total / chips if model_flops_total else None
    return Roofline(
        flops_per_chip=flops, bytes_per_chip=byts, coll_bytes_per_chip=cb,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=mf,
        useful_ratio=(mf / flops if (mf and flops) else None))


def model_flops(n_params_active: float, tokens: float,
                kind: str = "train") -> float:
    """MODEL_FLOPS = 6·N·D for training; 2·N·D for inference forward."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * n_params_active * tokens


def count_params(tree) -> int:
    import jax
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def active_fraction(cfg) -> float:
    """Active/total parameter fraction for MoE CompositeLM configs (1.0 for
    dense).  Routed expert params count as top_k/n_experts active."""
    try:
        groups = cfg.groups
    except AttributeError:
        return 1.0
    total = 0.0
    active = 0.0
    for g in groups:
        for b in g.cycle:
            d = b.d_model
            if b.mixer == "attn" and b.attn:
                a = b.attn
                w = d * (a.n_heads + 2 * a.n_kv_heads) * a.d_head \
                    + a.n_heads * a.d_head * d
            elif b.mixer == "mla" and b.mla:
                m = b.mla
                qd = m.qk_nope_dim + m.qk_rope_dim
                if m.q_lora_rank:
                    w = d * m.q_lora_rank + m.q_lora_rank * m.n_heads * qd
                else:
                    w = d * m.n_heads * qd
                w += d * (m.kv_lora_rank + m.qk_rope_dim)
                w += m.kv_lora_rank * m.n_heads * (m.qk_nope_dim
                                                   + m.v_head_dim)
                w += m.n_heads * m.v_head_dim * d
            elif b.mixer == "ssm" and b.ssm:
                s = b.ssm
                w = d * (2 * s.d_inner + 2 * s.n_groups * s.d_state
                         + s.n_heads) + s.d_inner * d
            else:
                w = 0.0
            n_rep = g.repeats if not b.shared else 1
            total += w * n_rep
            active += w * n_rep
            if b.ffn == "mlp" and b.mlp:
                f = 3 * d * b.mlp.d_ff if b.mlp.gated else 2 * d * b.mlp.d_ff
                total += f * n_rep
                active += f * n_rep
            elif b.ffn == "moe" and b.moe:
                mo = b.moe
                routed = 3 * d * mo.d_ff * mo.n_experts
                shared = 3 * d * mo.d_ff * mo.n_shared
                total += (routed + shared) * n_rep
                active += (routed * mo.top_k / mo.n_experts + shared) * n_rep
    if total == 0:
        return 1.0
    return active / total
