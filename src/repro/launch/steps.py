"""Step builders: (train / prefill / decode) × (lm / whisper) as pure jit
targets, plus the sharding trees for params, optimizer state and inputs.

Used by the multi-pod dry-run (AOT ``.lower().compile()`` with
ShapeDtypeStruct inputs) and by the real CPU-scale training/serving drivers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, Arch, input_specs, make_cfg
from repro.models import lm as lm_mod
from repro.models import whisper as wh_mod
from repro.nn import sharding as shlib
from repro.optim import adam_init, adam_update


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec_for(mesh: Mesh, *rest) -> P:
    ba = _batch_axes(mesh)
    lead = ba if len(ba) != 1 else ba[0]
    return P(lead if ba else None, *rest)


def spec_to_sharding(mesh: Mesh, spec_tree, sds_tree=None):
    """PartitionSpec tree -> NamedSharding tree.  With ``sds_tree`` (matching
    ShapeDtypeStructs) each spec is shape-fitted first: mesh axes that do not
    divide their dim are dropped (JAX requires even sharding)."""
    if sds_tree is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda s: isinstance(s, P))
    return jax.tree.map(
        lambda s, x: NamedSharding(mesh, shlib.fit_spec(s, x.shape, mesh)),
        spec_tree, sds_tree, is_leaf=lambda s: isinstance(s, P))


def opt_spec(param_spec_tree):
    """Adam state mirrors the param specs; step counter replicated."""
    return {"mu": param_spec_tree, "nu": param_spec_tree, "step": P()}


def _needs_seq_shard(cfg, mesh: Mesh) -> Optional[str]:
    """Shard decode KV caches over the sequence dim instead of kv-heads when
    kv-heads cannot fill the model axis (e.g. GQA kv=2 on a 16-way axis)."""
    if "model" not in mesh.axis_names:
        return None
    msize = mesh.shape["model"]
    try:
        groups = cfg.groups
    except AttributeError:
        return None
    for g in groups:
        for b in g.cycle:
            if b.mixer == "attn" and b.attn.n_kv_heads % msize != 0:
                return "model"
    return None


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run needs for one (arch, shape)."""
    step_fn: Callable
    args: Tuple            # ShapeDtypeStruct pytrees, positionally
    in_shardings: Tuple
    out_shardings: Any
    donate_argnums: Tuple = ()


@dataclasses.dataclass(frozen=True)
class PerfOpts:
    """Beyond-paper performance options iterated in EXPERIMENTS.md §Perf.

    fsdp         — additionally shard params + Adam moments over the 'data'
                   (and 'pod') axes, ZeRO-3 style: grad all-reduces become
                   reduce-scatter + all-gather of 1/|data| shards and the
                   per-chip state bytes drop |data|-fold.
    bf16_moments — keep Adam mu/nu in bf16 (halves optimizer bytes).
    impl         — attention implementation for train/prefill:
                   'xla' (materialised scores), 'chunked' (lax.scan
                   online-softmax, O(bq·bk) working set), 'flash' (the
                   Pallas kernel).
    ring         — sliding-window decode caches become ring buffers of
                   `window` slots instead of full-sequence buffers.
    """
    fsdp: bool = False
    bf16_moments: bool = False
    impl: str = "xla"
    ring: bool = False
    moe_shardmap: bool = False   # expert-parallel dispatch via shard_map:
    # local per-data-shard dispatch + model-axis psum combine, replacing the
    # GSPMD global-scatter path whose (E·cap, D) buffers lower to full-size
    # all-reduces (§Perf iteration A2)

    @property
    def tag(self) -> str:
        parts = []
        if self.fsdp:
            parts.append("fsdp")
        if self.bf16_moments:
            parts.append("bf16m")
        if self.impl != "xla":
            parts.append(self.impl)
        if self.ring:
            parts.append("ring")
        if self.moe_shardmap:
            parts.append("moesm")
        return "-".join(parts) or "base"


def _fsdp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def fsdp_spec(spec: P, shape, mesh: Mesh) -> P:
    """Add the data(+pod) axes to the largest still-unsharded dim of a param
    (ZeRO-3).  Shape-fitting happens downstream in spec_to_sharding."""
    axes = _fsdp_axes(mesh)
    if not axes:
        return spec
    dprod = 1
    for a in axes:
        dprod *= mesh.shape[a]
    entries = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for e in entries:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if used & set(axes):
        return spec
    best, best_size = None, 0
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % dprod == 0 and s > best_size:
            best, best_size = i, s
    if best is None:
        return spec
    entries[best] = axes if len(axes) > 1 else axes[0]
    return P(*entries)


def apply_fsdp(spec_tree, sds_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, x: fsdp_spec(s, x.shape, mesh), spec_tree, sds_tree,
        is_leaf=lambda s: isinstance(s, P))


def _apply_ring(cfg):
    """Flip ring=True on every windowed attention block of a CompositeLM."""
    new_groups = []
    for g in cfg.groups:
        cycle = []
        for b in g.cycle:
            if b.mixer == "attn" and b.attn and b.attn.window:
                b = dataclasses.replace(
                    b, attn=dataclasses.replace(b.attn, ring=True))
            cycle.append(b)
        new_groups.append(dataclasses.replace(g, cycle=tuple(cycle)))
    return dataclasses.replace(cfg, groups=tuple(new_groups))


def _apply_moe_shardmap(cfg):
    """Switch every MoE block to the shard_map expert-parallel dispatch."""
    new_groups = []
    for g in cfg.groups:
        cycle = []
        for b in g.cycle:
            if b.ffn == "moe" and b.moe:
                b = dataclasses.replace(
                    b, moe=dataclasses.replace(b.moe, dispatch="shardmap"))
            cycle.append(b)
        new_groups.append(dataclasses.replace(g, cycle=tuple(cycle)))
    return dataclasses.replace(cfg, groups=tuple(new_groups))


def _loss_fn(arch: Arch, cfg, impl: str = "xla"):
    if arch.kind == "whisper":
        return functools.partial(wh_mod.whisper_loss, cfg=cfg)
    return functools.partial(lm_mod.lm_loss, cfg=cfg, impl=impl)


def params_and_specs(arch: Arch, cfg):
    if arch.kind == "whisper":
        p_sds = jax.eval_shape(
            lambda: wh_mod.whisper_init(jax.random.PRNGKey(0), cfg))
        spec = wh_mod.whisper_spec(cfg)
    else:
        p_sds = jax.eval_shape(
            lambda: lm_mod.lm_init(jax.random.PRNGKey(0), cfg))
        spec = lm_mod.lm_spec(cfg)
    return p_sds, spec


def build_step(arch: Arch, shape_name: str, mesh: Mesh, *,
               lr: float = 3e-4, impl: str = "xla", unroll: bool = False,
               opts: Optional[PerfOpts] = None) -> StepBundle:
    opts = opts or PerfOpts(impl=impl)
    impl = opts.impl
    sc = SHAPES[shape_name]
    cfg = make_cfg(arch, shape_name, unroll=unroll)
    if opts.ring and arch.kind != "whisper":
        cfg = _apply_ring(cfg)
    if opts.moe_shardmap and arch.kind != "whisper":
        cfg = _apply_moe_shardmap(cfg)
    step_kind, inputs = input_specs(arch, shape_name)
    if "cache" in inputs and arch.kind != "whisper":
        # rebuild the cache stand-ins from the (possibly ring-transformed)
        # config
        from repro.models.lm import lm_init_cache
        inputs = dict(inputs)
        inputs["cache"] = jax.eval_shape(
            lambda: lm_init_cache(cfg, sc.global_batch, sc.seq_len,
                                  dtype=jnp.bfloat16))
    p_sds, p_spec = params_and_specs(arch, cfg)
    if opts.fsdp:
        p_spec = apply_fsdp(p_spec, p_sds, mesh)
    p_shard = spec_to_sharding(mesh, p_spec, p_sds)
    repl = NamedSharding(mesh, P())

    def bspec(sds, *rest):
        """Batch-leading sharding, shape-fitted to the given SDS."""
        return NamedSharding(
            mesh, shlib.fit_spec(batch_spec_for(mesh, *rest), sds.shape,
                                 mesh))

    if step_kind == "train":
        moment_dtype = jnp.bfloat16 if opts.bf16_moments else jnp.float32
        opt_sds = jax.eval_shape(
            lambda p: adam_init(p, moment_dtype=moment_dtype), p_sds)
        opt_shard = spec_to_sharding(mesh, opt_spec(p_spec), opt_sds)
        loss = _loss_fn(arch, cfg, impl=impl)

        if arch.kind == "whisper":
            def train_step(params, opt, batch):
                (l, metrics), grads = jax.value_and_grad(
                    lambda p: loss(p, batch=batch), has_aux=True)(params)
                params, opt, om = adam_update(grads, opt, params, lr=lr,
                                              max_norm=1.0)
                return params, opt, {**metrics, **om}
            batch_sds = {k: inputs[k] for k in
                         ("frame_embeds", "tokens", "labels")}
            batch_shard = {
                "frame_embeds": bspec(inputs["frame_embeds"], None, None),
                "tokens": bspec(inputs["tokens"], None),
                "labels": bspec(inputs["labels"], None)}
        else:
            def train_step(params, opt, batch):
                (l, metrics), grads = jax.value_and_grad(
                    lambda p: loss(p, batch=batch), has_aux=True)(params)
                params, opt, om = adam_update(grads, opt, params, lr=lr,
                                              max_norm=1.0)
                return params, opt, {**metrics, **om}
            batch_sds = {k: v for k, v in inputs.items()}
            batch_shard = {"tokens": bspec(inputs["tokens"], None),
                           "labels": bspec(inputs["labels"], None)}
            if "prefix_embeds" in batch_sds:
                batch_shard["prefix_embeds"] = bspec(
                    inputs["prefix_embeds"], None, None)
        metric_shard = jax.tree.map(
            lambda _: repl,
            jax.eval_shape(train_step, p_sds, opt_sds, batch_sds)[2])
        return StepBundle(
            step_fn=train_step,
            args=(p_sds, opt_sds, batch_sds),
            in_shardings=(p_shard, opt_shard, batch_shard),
            out_shardings=(p_shard, opt_shard, metric_shard),
            donate_argnums=(0, 1))

    seq_shard = (_needs_seq_shard(cfg, mesh)
                 if step_kind == "decode" else None)
    if arch.kind == "whisper":
        cache_spec = wh_mod.whisper_cache_spec(cfg, seq_shard=seq_shard)
    else:
        cache_spec = lm_mod.lm_cache_spec(cfg, seq_shard=seq_shard)
    with shlib.use_mesh(mesh):
        cache_shard = spec_to_sharding(mesh, cache_spec, inputs["cache"])

    def logits_shard_for(step_fn, args):
        logits_sds = jax.eval_shape(step_fn, *args)[0]
        return bspec(logits_sds, None, "model")

    if step_kind == "prefill":
        if arch.kind == "whisper":
            def prefill_step(params, frame_embeds, tokens, cache):
                return wh_mod.whisper_prefill(params, cfg, frame_embeds,
                                              tokens, cache)
            args = (p_sds, inputs["frame_embeds"], inputs["tokens"],
                    inputs["cache"])
            in_sh = (p_shard, bspec(inputs["frame_embeds"], None, None),
                     bspec(inputs["tokens"], None), cache_shard)
        elif "prefix_embeds" in inputs:
            def prefill_step(params, prefix_embeds, tokens, cache):
                return lm_mod.lm_prefill(params, cfg, tokens, cache,
                                         prefix_embeds=prefix_embeds,
                                         impl=impl)
            args = (p_sds, inputs["prefix_embeds"], inputs["tokens"],
                    inputs["cache"])
            in_sh = (p_shard, bspec(inputs["prefix_embeds"], None, None),
                     bspec(inputs["tokens"], None), cache_shard)
        else:
            def prefill_step(params, tokens, cache):
                return lm_mod.lm_prefill(params, cfg, tokens, cache,
                                         impl=impl)
            args = (p_sds, inputs["tokens"], inputs["cache"])
            in_sh = (p_shard, bspec(inputs["tokens"], None), cache_shard)
        with shlib.use_mesh(mesh):
            lsh = logits_shard_for(prefill_step, args)
        return StepBundle(
            step_fn=prefill_step, args=args, in_shardings=in_sh,
            out_shardings=(lsh, cache_shard),
            donate_argnums=(len(args) - 1,))

    # decode
    if arch.kind == "whisper":
        def decode_step(params, token, cache, pos):
            return wh_mod.whisper_decode(params, cfg, token, cache, pos)
    else:
        def decode_step(params, token, cache, pos):
            return lm_mod.lm_decode(params, cfg, token, cache, pos)
    args = (p_sds, inputs["token"], inputs["cache"], inputs["pos"])
    in_sh = (p_shard, bspec(inputs["token"], None), cache_shard, repl)
    with shlib.use_mesh(mesh):
        lsh = logits_shard_for(decode_step, args)
    return StepBundle(
        step_fn=decode_step, args=args, in_shardings=in_sh,
        out_shardings=(lsh, cache_shard),
        donate_argnums=(2,))
