import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT ``jit(step).lower(**ShapeDtypeStructs).compile()``
for every (architecture × input shape × mesh) — proves the distribution
config is coherent without hardware.  The XLA_FLAGS line above MUST run
before any jax import (device count locks at first init), and only here —
smoke tests and benches see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both]
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs import (ARCH_IDS, SHAPES, canonical_id, get_arch,
                           input_specs, make_cfg, supports)
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, params_and_specs
from repro.nn import sharding as shlib


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    if not out:
        out["repr"] = str(mem)
    return out


def run_one(arch_name: str, shape: str, *, multi_pod: bool,
            out_dir: str = "experiments/dryrun", lr: float = 3e-4,
            save: bool = True, unroll: bool = True,
            opts=None) -> dict:
    from repro.launch.steps import PerfOpts
    opts = opts or PerfOpts()
    arch = get_arch(arch_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch.name, "shape": shape, "mesh": mesh_name,
           "family": arch.family, "cite": arch.cite,
           "opts": opts.tag}
    ok, why = supports(arch, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = make_cfg(arch, shape, unroll=unroll)
    with shlib.use_mesh(mesh), mesh:
        bundle = build_step(arch, shape, mesh, lr=lr, unroll=unroll,
                            opts=opts)
        jf = jax.jit(bundle.step_fn, in_shardings=bundle.in_shardings,
                     out_shardings=bundle.out_shardings,
                     donate_argnums=bundle.donate_argnums)
        lowered = jf.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    try:
        mem = _mem_dict(compiled.memory_analysis())
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    text = compiled.as_text()
    coll = rl.collective_bytes(text)

    # MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
    sc = SHAPES[shape]
    n_params = rl.count_params(bundle.args[0])
    frac = rl.active_fraction(cfg)
    tokens = sc.global_batch * (sc.seq_len if sc.step != "decode" else 1)
    mf = rl.model_flops(n_params * frac, tokens,
                        "train" if sc.step == "train" else "infer")
    roof = rl.roofline(cost, coll, chips=chips, model_flops_total=mf)

    rec.update({
        "status": "ok", "step": sc.step, "chips": chips, "unroll": unroll,
        "seq_len": sc.seq_len, "global_batch": sc.global_batch,
        "n_params": int(n_params), "active_frac": frac,
        "tokens_per_step": tokens,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "collectives": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll.get("counts", {}),
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "roofline": roof.as_dict(),
    })
    if save:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if opts.tag == "base" else f"_{opts.tag}"
        fn = f"{canonical_id(arch_name)}_{shape}_{mesh_name}{suffix}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def _summary_line(rec: dict) -> str:
    if rec["status"] != "ok":
        return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:10s} "
                f"SKIP ({rec['reason'][:40]}...)")
    r = rec["roofline"]
    mem_gb = rec["memory"].get("temp_size_in_bytes", 0) / 2**30
    arg_gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
    return (f"{rec['arch']:18s} {rec['shape']:12s} {rec['mesh']:10s} "
            f"comp {r['compute_s']:9.4f}s mem {r['memory_s']:9.4f}s "
            f"coll {r['collective_s']:9.4f}s -> {r['bottleneck']:10s} "
            f"| arg {arg_gb:7.2f}GiB tmp {mem_gb:7.2f}GiB "
            f"| lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--scan", dest="unroll", action="store_false",
                    help="keep lax.scan layer stacks (faster compile, but "
                         "XLA cost_analysis undercounts while-loop flops)")
    ap.add_argument("--fsdp", action="store_true",
                    help="ZeRO-3: shard params+moments over data/pod axes")
    ap.add_argument("--bf16-moments", action="store_true")
    ap.add_argument("--impl", default="xla",
                    choices=["xla", "chunked", "flash"])
    ap.add_argument("--ring", action="store_true",
                    help="ring-buffer sliding-window decode caches")
    ap.add_argument("--moe-shardmap", action="store_true",
                    help="expert-parallel MoE dispatch via shard_map")
    args = ap.parse_args()
    from repro.launch.steps import PerfOpts
    opts = PerfOpts(fsdp=args.fsdp, bf16_moments=args.bf16_moments,
                    impl=args.impl, ring=args.ring,
                    moe_shardmap=args.moe_shardmap)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both else [args.multi_pod]

    failures = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                try:
                    rec = run_one(a, s, multi_pod=mp, out_dir=args.out,
                                  unroll=args.unroll, opts=opts)
                    print(_summary_line(rec), flush=True)
                except Exception as e:
                    failures.append((a, s, mp, repr(e)))
                    print(f"{a:18s} {s:12s} {'mp' if mp else 'sp':10s} "
                          f"FAIL {e!r}", flush=True)
                    if not args.continue_on_error:
                        traceback.print_exc()
                        raise
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nAll dry-runs lowered + compiled successfully.")


if __name__ == "__main__":
    main()
