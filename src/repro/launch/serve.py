"""Serving driver — continuous-batching engine demo at smoke scale.

Usage:
  python -m repro.launch.serve --arch qwen2-0.5b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.serving import Engine, ServeCfg


def serve_demo(arch_name: str, *, n_requests: int = 8, max_batch: int = 4,
               max_seq: int = 256, seed: int = 0):
    arch = get_arch(arch_name)
    if arch.kind == "whisper":
        raise SystemExit("whisper serving demo: use examples/serve_edge.py")
    cfg = arch.make_smoke()
    key = jax.random.PRNGKey(seed)
    params = lm_mod.lm_init(key, cfg)
    eng = Engine(cfg, params, ServeCfg(max_batch=max_batch, max_seq=max_seq))
    rng = np.random.default_rng(seed)
    reqs = [(i, rng.integers(0, cfg.vocab, size=rng.integers(4, 32),
                             dtype=np.int32), int(rng.integers(4, 24)))
            for i in range(n_requests)]
    t0 = time.perf_counter()
    done, stats = eng.run(reqs)
    wall = time.perf_counter() - t0
    total_toks = sum(len(v) for v in done.values())
    print(f"arch={arch.name} (smoke) requests={n_requests} "
          f"generated={total_toks} tokens in {wall:.2f}s "
          f"({total_toks / wall:.1f} tok/s, "
          f"{stats['decode_steps']} batched decode steps)")
    return done, stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()
    serve_demo(args.arch, n_requests=args.requests,
               max_batch=args.max_batch, max_seq=args.max_seq)


if __name__ == "__main__":
    main()
