"""Training driver — runs for real at CPU/smoke scale, and is the same code
path the dry-run lowers for the production meshes.

Usage (CPU-scale end-to-end):
  python -m repro.launch.train --arch qwen2-0.5b --smoke --steps 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import bf16_safe_cast as _cast, save_pytree
from repro.configs import get_arch
from repro.data import make_lm_batch
from repro.models import lm as lm_mod
from repro.models import whisper as wh_mod
from repro.optim import adam_init, adam_update, linear_warmup_cosine


def make_train_fns(arch, cfg, *, lr_schedule, impl: str = "xla"):
    if arch.kind == "whisper":
        loss_fn = lambda p, batch: wh_mod.whisper_loss(p, cfg, batch)
        init_fn = lambda key: wh_mod.whisper_init(key, cfg)
    else:
        loss_fn = lambda p, batch: lm_mod.lm_loss(p, cfg, batch, impl=impl)
        init_fn = lambda key: lm_mod.lm_init(key, cfg)

    @jax.jit
    def train_step(params, opt, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch), has_aux=True)(params)
        lr = lr_schedule(opt["step"])
        params, opt, om = adam_update(grads, opt, params, lr=lr,
                                      max_norm=1.0)
        return params, opt, {**metrics, **om, "lr": lr}

    return init_fn, train_step


def make_batch_fn(arch, cfg, *, batch: int, seq_len: int):
    """Synthetic batch matched to the arch's modality."""
    n_pre = getattr(arch, "n_prefix", 0)

    def fn(key):
        if arch.kind == "whisper":
            kb, kf = jax.random.split(key)
            b = make_lm_batch(kb, vocab=cfg.vocab, batch=batch,
                              seq_len=seq_len)
            b["frame_embeds"] = 0.02 * jax.random.normal(
                kf, (batch, cfg.n_frames, cfg.d_model))
            return b
        if n_pre and arch.prefix_embed_dim:
            kb, kp = jax.random.split(key)
            npre = min(n_pre, seq_len // 2)
            b = make_lm_batch(kb, vocab=cfg.vocab, batch=batch,
                              seq_len=seq_len)
            b["tokens"] = b["tokens"][:, : seq_len - npre]
            b["prefix_embeds"] = 0.02 * jax.random.normal(
                kp, (batch, npre, arch.prefix_embed_dim))
            return b
        return make_lm_batch(key, vocab=cfg.vocab, batch=batch,
                             seq_len=seq_len)
    return fn


def train_loop(arch_name: str, *, smoke: bool = True, steps: int = 200,
               batch: int = 8, seq_len: int = 128, lr: float = 3e-4,
               log_every: int = 20, seed: int = 0, impl: str = "xla",
               ckpt: str = ""):
    arch = get_arch(arch_name)
    cfg = arch.make_smoke() if smoke else arch.make_full()
    # VLM smoke: the reduced config has its own (small) prefix size
    if getattr(cfg, "prefix_embed_dim", 0):
        arch = arch.__class__(**{**arch.__dict__,
                                 "n_prefix": cfg.n_prefix,
                                 "prefix_embed_dim": cfg.prefix_embed_dim})
    sched = linear_warmup_cosine(lr, warmup=min(20, steps // 10 + 1),
                                 steps=steps)
    init_fn, train_step = make_train_fns(arch, cfg, lr_schedule=sched,
                                         impl=impl)
    batch_fn = make_batch_fn(arch, cfg, batch=batch, seq_len=seq_len)
    key = jax.random.PRNGKey(seed)
    params = init_fn(key)
    opt = adam_init(params)
    hist = []
    t0 = time.time()
    for step in range(steps):
        b = batch_fn(jax.random.fold_in(key, step))
        params, opt, m = train_step(params, opt, b)
        hist.append(float(m["loss"]))
        if log_every and (step + 1) % log_every == 0:
            print(f"step {step + 1:5d} loss {hist[-1]:7.4f} "
                  f"xent {float(m['xent']):7.4f} "
                  f"gnorm {float(m['gnorm']):8.3f} "
                  f"({(time.time() - t0) / (step + 1):.2f} s/step)",
                  flush=True)
    if ckpt:
        save_pytree(ckpt, _cast({"params": params, "opt": opt}))
        print(f"saved checkpoint to {ckpt}")
    return params, hist


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="xla", choices=["xla", "flash",
                                                      "pallas"])
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    _, hist = train_loop(args.arch, smoke=args.smoke, steps=args.steps,
                         batch=args.batch, seq_len=args.seq_len, lr=args.lr,
                         impl=args.impl, ckpt=args.ckpt, seed=args.seed)
    print(f"final loss {hist[-1]:.4f} (first {hist[0]:.4f})")


if __name__ == "__main__":
    main()
