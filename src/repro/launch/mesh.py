"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """1×1 mesh over the local device — smoke tests / CPU examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_cells_mesh(n_devices: int | None = None) -> Mesh:
    """1-D ``("cells",)`` mesh for sharding independent edge cells across
    devices (``repro.core.t2drl.run_training_sharded``, DESIGN.md §13).

    ``n_devices`` defaults to every visible device; on CPU, multiple
    devices are obtained with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    n = len(jax.devices()) if n_devices is None else n_devices
    return jax.make_mesh((n,), ("cells",))


def batch_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_sharding(mesh: Mesh, *rest) -> NamedSharding:
    ba = batch_axes(mesh)
    lead = ba if len(ba) != 1 else ba[0]
    return NamedSharding(mesh, P(lead, *rest))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
