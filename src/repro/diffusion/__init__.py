from .schedule import DiffusionSchedule, make_schedule  # noqa: F401
from .denoiser import (denoiser_init, denoiser_apply,  # noqa: F401
                       denoiser_apply_stacked, time_embedding)
from .sampler import (reverse_sample, reverse_sample_actions,  # noqa: F401
                      reverse_sample_actions_stacked,
                      reverse_sample_actions_stacked_stats,
                      reverse_sample_actions_stats, reverse_sample_stacked)
