from .schedule import DiffusionSchedule, make_schedule  # noqa: F401
from .denoiser import denoiser_init, denoiser_apply, time_embedding  # noqa: F401
from .sampler import reverse_sample, reverse_sample_actions  # noqa: F401
