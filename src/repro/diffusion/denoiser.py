"""Conditional noise-prediction MLP used as the D3PG actor core.

Matches the paper's setup: 3 fully-connected hidden layers of 128 neurons
learning the noise  eps_hat(x_l, l, s)  — the denoising-step index l enters
through a sinusoidal time embedding, the environment state s through plain
concatenation (the "text prompt" of the resource-allocation diffusion)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def time_embedding(l, dim: int = 16):
    """Sinusoidal embedding of the (integer) denoising step.  l: scalar or
    (B,) -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(1000.0) * jnp.arange(half) / half)
    ang = jnp.asarray(l, jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


TIME_DIM = 16


def denoiser_init(key, state_dim: int, action_dim: int, *,
                  hidden: int = 128, n_layers: int = 3,
                  time_dim: int = TIME_DIM):
    dims = [action_dim + state_dim + time_dim] + [hidden] * n_layers + [action_dim]
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for k, (i, o) in zip(keys, zip(dims[:-1], dims[1:])):
        w = jax.random.normal(k, (i, o)) * (1.0 / math.sqrt(i))
        layers.append({"w": w.astype(jnp.float32), "b": jnp.zeros(o)})
    return {"layers": layers}


def denoiser_apply(p, x, l, state, *, time_dim: int = TIME_DIM):
    """eps_hat = f(x_l, l, s).  x: (..., A); l scalar/(...); state: (..., S)."""
    te = time_embedding(l, time_dim)
    te = jnp.broadcast_to(te, x.shape[:-1] + te.shape[-1:])
    h = jnp.concatenate([x, state, te], axis=-1)
    layers = p["layers"]
    for layer in layers[:-1]:
        h = jax.nn.relu(h @ layer["w"] + layer["b"])
    out = h @ layers[-1]["w"] + layers[-1]["b"]
    return out


def _stacked_linear(x, w, b):
    # mirrors repro.core.networks.stacked_linear; duplicated (5 lines) so
    # repro.diffusion never imports the repro.core package surface — d3pg
    # imports this package, and a back-import would cycle at init time
    y = jnp.einsum("b...i,bio->b...o", x, w)
    return y + b.reshape((b.shape[0],) + (1,) * (y.ndim - 2) + (b.shape[-1],))


def denoiser_apply_stacked(p, x, l, state, *, time_dim: int = TIME_DIM):
    """``denoiser_apply`` over B stacked parameter sets (DESIGN.md §13).

    p: per-learner params with a leading ``(B,)`` axis on every leaf;
    x: ``(B, ..., A)``; state: ``(B, ..., S)``; l: scalar denoising step
    shared by the whole stack.  One batched ``(B, ..., in) × (B, in, out)``
    contraction per layer — bit-identical to ``jax.vmap(denoiser_apply)``
    (pinned by ``tests/test_fused.py``)."""
    te = time_embedding(l, time_dim)
    te = jnp.broadcast_to(te, x.shape[:-1] + te.shape[-1:])
    h = jnp.concatenate([x, state, te], axis=-1)
    layers = p["layers"]
    for layer in layers[:-1]:
        h = jax.nn.relu(_stacked_linear(h, layer["w"], layer["b"]))
    return _stacked_linear(h, layers[-1]["w"], layers[-1]["b"])
