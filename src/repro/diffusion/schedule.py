"""DDPM noise schedules.

The paper (Sec. 5.2.1) uses the exponential VP schedule

    beta_l = 1 - exp( -beta_min/L - (2l-1)/(2 L^2) (beta_max - beta_min) )

for l = 1..L.  We precompute alpha, alpha-bar and the posterior variance
beta-tilde used by the reverse process (Eq. 17).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    betas: jnp.ndarray        # (L,)
    alphas: jnp.ndarray       # (L,)
    alpha_bars: jnp.ndarray   # (L,)  cumulative products
    beta_tildes: jnp.ndarray  # (L,)  posterior variances

    @property
    def L(self) -> int:
        return self.betas.shape[0]


def make_schedule(L: int, *, beta_min: float = 0.1, beta_max: float = 10.0,
                  kind: str = "paper") -> DiffusionSchedule:
    l = jnp.arange(1, L + 1, dtype=jnp.float32)
    if kind == "paper":           # the paper's exponential VP schedule
        betas = 1.0 - jnp.exp(-beta_min / L
                              - (2 * l - 1) / (2 * L**2) * (beta_max - beta_min))
    elif kind == "linear":        # Ho et al. DDPM default (image side)
        betas = jnp.linspace(1e-4, 0.02, L)
    elif kind == "cosine":
        s = 0.008
        f = jnp.cos((jnp.arange(L + 1) / L + s) / (1 + s) * jnp.pi / 2) ** 2
        betas = jnp.clip(1.0 - f[1:] / f[:-1], 0.0, 0.999)
    else:
        raise ValueError(kind)
    alphas = 1.0 - betas
    alpha_bars = jnp.cumprod(alphas)
    prev = jnp.concatenate([jnp.ones(1), alpha_bars[:-1]])
    beta_tildes = (1.0 - prev) / (1.0 - alpha_bars) * betas
    return DiffusionSchedule(betas, alphas, alpha_bars, beta_tildes)
