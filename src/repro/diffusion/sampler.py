"""Reverse-process sampling (Eq. 17-20): the D3PG action generator.

Starting from x_L ~ N(0, I), iterate

    mu_l  = 1/sqrt(a_l) [ x_l - (1-a_l)/sqrt(1-abar_l) eps_hat(x_l, l, s) ]
    x_{l-1} = mu_l + sqrt(beta_tilde_l) eps,   eps ~ N(0,I)   (l > 1)

Gradients flow through the entire chain (reparameterised), which is what the
deterministic policy gradient in D3PG differentiates.  The final x_0 is
squashed by tanh into [-1, 1] and affinely mapped to [0, 1] — the paper's raw
action range before the action amender.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .denoiser import denoiser_apply, denoiser_apply_stacked
from .schedule import DiffusionSchedule


def reverse_sample(p, sched: DiffusionSchedule, state, key, action_dim: int,
                   *, impl: str = "xla"):
    """One reverse chain.  state: (..., S) -> x0: (..., A) in [-1, 1]."""
    L = sched.L
    batch_shape = state.shape[:-1]
    kx, ke = jax.random.split(key)
    x_L = jax.random.normal(kx, batch_shape + (action_dim,))
    noises = jax.random.normal(ke, (L,) + batch_shape + (action_dim,))

    def step(x, inp):
        l_rev, eps_noise = inp          # l_rev runs L-1 .. 0 (0-based index)
        eps_hat = denoiser_apply(p, x, (l_rev + 1).astype(jnp.float32), state)
        alpha = sched.alphas[l_rev]
        abar = sched.alpha_bars[l_rev]
        btilde = sched.beta_tildes[l_rev]
        if impl == "pallas":
            from repro.kernels import ops as kops
            x = kops.ddpm_step(x, eps_hat, eps_noise, alpha, abar, btilde,
                               l_rev)
        else:
            mu = (x - (1 - alpha) / jnp.sqrt(1 - abar) * eps_hat) \
                / jnp.sqrt(alpha)
            # no noise at the last step (l_rev == 0)
            x = mu + jnp.where(l_rev > 0, jnp.sqrt(btilde), 0.0) * eps_noise
        return x, None

    ls = jnp.arange(L - 1, -1, -1)
    x0, _ = jax.lax.scan(step, x_L, (ls, noises))
    return jnp.tanh(x0)


def reverse_sample_actions(p, sched: DiffusionSchedule, state, key,
                           action_dim: int, *, impl: str = "xla"):
    """Action in [0, 1]^A (the paper's raw action range)."""
    x0 = reverse_sample(p, sched, state, key, action_dim, impl=impl)
    return 0.5 * (x0 + 1.0)


def reverse_sample_actions_stats(p, sched: DiffusionSchedule, state, key,
                                 action_dim: int):
    """Telemetry variant of ``reverse_sample_actions``: additionally
    returns ``{"denoise_mag": (L,)}`` — the mean |eps_hat| per reverse
    step, ordered l = L .. 1 (chain direction, noisiest first) — emitted
    as scan ys so the tap stays inside the compiled program.  Same PRNG
    consumption and same x-update arithmetic as the plain sampler."""
    L = sched.L
    batch_shape = state.shape[:-1]
    kx, ke = jax.random.split(key)
    x_L = jax.random.normal(kx, batch_shape + (action_dim,))
    noises = jax.random.normal(ke, (L,) + batch_shape + (action_dim,))

    def step(x, inp):
        l_rev, eps_noise = inp
        eps_hat = denoiser_apply(p, x, (l_rev + 1).astype(jnp.float32), state)
        alpha = sched.alphas[l_rev]
        abar = sched.alpha_bars[l_rev]
        btilde = sched.beta_tildes[l_rev]
        mu = (x - (1 - alpha) / jnp.sqrt(1 - abar) * eps_hat) \
            / jnp.sqrt(alpha)
        x = mu + jnp.where(l_rev > 0, jnp.sqrt(btilde), 0.0) * eps_noise
        return x, jnp.mean(jnp.abs(eps_hat))

    ls = jnp.arange(L - 1, -1, -1)
    x0, mag = jax.lax.scan(step, x_L, (ls, noises))
    return 0.5 * (jnp.tanh(x0) + 1.0), {"denoise_mag": mag}


def reverse_sample_stacked(p, sched: DiffusionSchedule, state, keys,
                           action_dim: int):
    """B fused reverse chains: one L-step scan denoises all B actors per
    step (DESIGN.md §13).

    p: stacked denoiser params (leading ``(B,)`` on every leaf); state:
    ``(B, ..., S)``; keys: ``(B, 2)`` — one chain key per learner, split
    and consumed exactly as the per-learner ``reverse_sample`` does, so
    the PRNG stream (and hence the output) is bit-identical to
    ``jax.vmap(reverse_sample)`` (pinned by ``tests/test_fused.py``).
    The per-learner noise draws stay vmapped (elementwise threefry fuses
    fine); what the fused path buys is the denoiser matmuls of all B
    learners advancing as single batched contractions inside ONE scan
    instead of B interleaved small per-learner programs."""
    L = sched.L
    batch_shape = state.shape[1:-1]
    kk = jax.vmap(jax.random.split)(keys)                       # (B, 2, 2)
    x_L = jax.vmap(
        lambda k: jax.random.normal(k, batch_shape + (action_dim,)))(kk[:, 0])
    noises = jax.vmap(
        lambda k: jax.random.normal(
            k, (L,) + batch_shape + (action_dim,)))(kk[:, 1])
    noises = jnp.moveaxis(noises, 1, 0)                # (L, B, ..., A)

    def step(x, inp):
        l_rev, eps_noise = inp          # l_rev runs L-1 .. 0 (0-based index)
        eps_hat = denoiser_apply_stacked(
            p, x, (l_rev + 1).astype(jnp.float32), state)
        alpha = sched.alphas[l_rev]
        abar = sched.alpha_bars[l_rev]
        btilde = sched.beta_tildes[l_rev]
        mu = (x - (1 - alpha) / jnp.sqrt(1 - abar) * eps_hat) \
            / jnp.sqrt(alpha)
        x = mu + jnp.where(l_rev > 0, jnp.sqrt(btilde), 0.0) * eps_noise
        return x, None

    ls = jnp.arange(L - 1, -1, -1)
    x0, _ = jax.lax.scan(step, x_L, (ls, noises))
    return jnp.tanh(x0)


def reverse_sample_actions_stacked(p, sched: DiffusionSchedule, state, keys,
                                   action_dim: int):
    """Stacked-learner action in [0, 1]^A; see ``reverse_sample_stacked``."""
    x0 = reverse_sample_stacked(p, sched, state, keys, action_dim)
    return 0.5 * (x0 + 1.0)


def reverse_sample_actions_stacked_stats(p, sched: DiffusionSchedule, state,
                                         keys, action_dim: int):
    """Telemetry variant of ``reverse_sample_actions_stacked``: also
    returns ``{"denoise_mag": (B, L)}`` — per-learner mean |eps_hat| per
    reverse step, ordered l = L .. 1.  PRNG stream identical to the plain
    stacked sampler."""
    L = sched.L
    batch_shape = state.shape[1:-1]
    kk = jax.vmap(jax.random.split)(keys)                       # (B, 2, 2)
    x_L = jax.vmap(
        lambda k: jax.random.normal(k, batch_shape + (action_dim,)))(kk[:, 0])
    noises = jax.vmap(
        lambda k: jax.random.normal(
            k, (L,) + batch_shape + (action_dim,)))(kk[:, 1])
    noises = jnp.moveaxis(noises, 1, 0)                # (L, B, ..., A)

    def step(x, inp):
        l_rev, eps_noise = inp
        eps_hat = denoiser_apply_stacked(
            p, x, (l_rev + 1).astype(jnp.float32), state)
        alpha = sched.alphas[l_rev]
        abar = sched.alpha_bars[l_rev]
        btilde = sched.beta_tildes[l_rev]
        mu = (x - (1 - alpha) / jnp.sqrt(1 - abar) * eps_hat) \
            / jnp.sqrt(alpha)
        x = mu + jnp.where(l_rev > 0, jnp.sqrt(btilde), 0.0) * eps_noise
        # per-learner mean over every non-B axis
        mag = jnp.mean(jnp.abs(eps_hat),
                       axis=tuple(range(1, eps_hat.ndim)))
        return x, mag

    ls = jnp.arange(L - 1, -1, -1)
    x0, mag = jax.lax.scan(step, x_L, (ls, noises))    # mag: (L, B)
    return (0.5 * (jnp.tanh(x0) + 1.0),
            {"denoise_mag": jnp.moveaxis(mag, 0, 1)})  # (B, L)
