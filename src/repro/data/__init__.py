from .synthetic import (lm_batch_stream, make_lm_batch,  # noqa: F401
                        request_stream)
