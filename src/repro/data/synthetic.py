"""Synthetic data pipelines.

``make_lm_batch`` produces *learnable* token streams (a noisy order-k Markov
chain over the vocabulary) so end-to-end training examples show a genuinely
decreasing loss, not just moving numbers.  ``request_stream`` generates the
AIGC request workload (Zipf-over-models with Markov-modulated skewness) used
by the serving gateway and examples.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp


def make_lm_batch(key, *, vocab: int, batch: int, seq_len: int,
                  structure: float = 0.8):
    """Noisy deterministic-successor stream: token_{t+1} = (a·token_t + c)
    mod vocab with prob ``structure``, uniform otherwise.  Returns
    {"tokens", "labels"} with labels = next-token targets."""
    k1, k2, k3 = jax.random.split(key, 3)
    a, c = 31, 17  # coprime with any pow2-ish vocab; fixed affine successor
    first = jax.random.randint(k1, (batch, 1), 0, vocab)
    noise = jax.random.randint(k2, (batch, seq_len), 0, vocab)
    use_rule = jax.random.uniform(k3, (batch, seq_len)) < structure

    def step(tok, inp):
        nz, ur = inp
        nxt = jnp.where(ur, (a * tok + c) % vocab, nz)
        return nxt, nxt

    _, toks = jax.lax.scan(step, first[:, 0],
                           (noise.T, use_rule.T))
    tokens = jnp.concatenate([first, toks.T[:, :-1]], axis=1)
    labels = toks.T
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


def lm_batch_stream(seed: int, *, vocab: int, batch: int, seq_len: int,
                    structure: float = 0.8) -> Iterator[dict]:
    key = jax.random.PRNGKey(seed)
    step = 0
    while True:
        yield make_lm_batch(jax.random.fold_in(key, step), vocab=vocab,
                            batch=batch, seq_len=seq_len,
                            structure=structure)
        step += 1


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    model_id: int
    prompt_len: int
    max_new_tokens: int
    arrival: float


def request_stream(seed: int, *, n_models: int, gamma: float = 0.5,
                   rate: float = 2.0, prompt_len=(16, 128),
                   new_tokens=(8, 64), n: Optional[int] = None):
    """Poisson arrivals of AIGC requests with Zipf(model) popularity."""
    import numpy as np
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_models + 1, dtype=np.float64)
    probs = ranks ** -gamma
    probs /= probs.sum()
    t, i = 0.0, 0
    while n is None or i < n:
        t += rng.exponential(1.0 / rate)
        yield Request(
            uid=i,
            model_id=int(rng.choice(n_models, p=probs)),
            prompt_len=int(rng.integers(*prompt_len)),
            max_new_tokens=int(rng.integers(*new_tokens)),
            arrival=t)
        i += 1
