"""Adam/AdamW with dtype-configurable moments and global-norm clipping.

Moments may be kept in bf16 (``moment_dtype``) — used for the very large MoE
configs where fp32 Adam state does not fit the pod (DESIGN.md §10)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adam_init(params, *, moment_dtype=jnp.float32):
    zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.int32(0)}


def adam_update(grads, state, params, *, lr, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8,
                weight_decay: float = 0.0, max_norm: float = 0.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if max_norm:
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state["step"] + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
        delta = lr * (mu_n / b1c) / (jnp.sqrt(nu_n / b2c) + eps)
        if weight_decay:
            delta = delta + lr * weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                mu_n.astype(mu.dtype), nu_n.astype(nu.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, mu, nu, p)
           for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"gnorm": gnorm}


# -- fused B-learner Adam (DESIGN.md §13) -------------------------------------
#
# Stacked layout: every param/moment leaf carries a leading (B,) learner
# axis and the step counter is (B,) int32 — exactly what
# jax.vmap(adam_init) produces, so vmapped and fused states interchange
# freely.  The fused update advances all B learners in ONE elementwise
# pass per leaf (per-learner scalars broadcast over trailing axes) instead
# of B per-learner passes; bit-identity with jax.vmap(adam_update) is
# pinned by tests/test_fused.py.


def _per_learner(v, ndim):
    """Broadcast a per-learner (B,) scalar against a (B, ...) leaf of rank
    ``ndim`` (python scalars pass through)."""
    v = jnp.asarray(v, jnp.float32)
    if v.ndim == 0:
        return v
    return v.reshape(v.shape + (1,) * (ndim - 1))


def global_norm_stacked(tree):
    """Per-learner global norms: (B,) — one reduction over the non-learner
    axes of every leaf, summed across leaves in flatten order (the same
    accumulation order the vmapped per-learner norm uses)."""
    total = None
    for x in jax.tree.leaves(tree):
        s = jnp.sum(jnp.square(x.astype(jnp.float32)),
                    axis=tuple(range(1, x.ndim)))
        total = s if total is None else total + s
    return jnp.sqrt(total)


def adam_init_stacked(params, *, moment_dtype=jnp.float32):
    """Fresh optimizer state for stacked (leading ``(B,)``) params —
    layout-identical to ``jax.vmap(adam_init)``."""
    B = jax.tree.leaves(params)[0].shape[0]
    zeros = lambda p: jnp.zeros_like(p, dtype=moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((B,), jnp.int32)}


def adam_update_stacked(grads, state, params, *, lr, b1: float = 0.9,
                        b2: float = 0.999, eps: float = 1e-8,
                        weight_decay: float = 0.0, max_norm: float = 0.0):
    """B independent Adam steps fused into one batched pass.

    ``grads``/``state``/``params`` leaves carry a leading ``(B,)`` learner
    axis; ``lr`` is a python scalar or a per-learner ``(B,)`` array (the
    population-sweep lever, DESIGN.md §13).  Returns
    ``(new_params, new_state, {"gnorm": (B,)})``."""
    gnorm = global_norm_stacked(grads)
    if max_norm:
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
        grads = jax.tree.map(
            lambda g: g * _per_learner(scale, g.ndim), grads)
    step = state["step"] + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)          # (B,)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, p):
        gf = g.astype(jnp.float32)
        mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * gf
        nu_n = b2 * nu.astype(jnp.float32) + (1 - b2) * gf * gf
        lr_b = _per_learner(lr, p.ndim)
        delta = lr_b * (mu_n / _per_learner(b1c, p.ndim)) \
            / (jnp.sqrt(nu_n / _per_learner(b2c, p.ndim)) + eps)
        if weight_decay:
            delta = delta + lr_b * weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - delta).astype(p.dtype),
                mu_n.astype(mu.dtype), nu_n.astype(nu.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(g, mu, nu, p)
           for g, mu, nu, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"gnorm": gnorm}
