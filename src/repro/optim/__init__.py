from .adam import (adam_init, adam_init_stacked, adam_update,  # noqa: F401
                   adam_update_stacked, clip_by_global_norm, global_norm,
                   global_norm_stacked)
from .schedules import constant, cosine_decay, linear_warmup_cosine  # noqa: F401
