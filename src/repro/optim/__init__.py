from .adam import (adam_init, adam_update, clip_by_global_norm,  # noqa: F401
                   global_norm)
from .schedules import constant, cosine_decay, linear_warmup_cosine  # noqa: F401
