"""Learning-rate schedules (callable on an int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)
    return f


def linear_warmup_cosine(lr: float, warmup: int, steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(steps - warmup, 1), final_frac)
    def f(step):
        w = jnp.minimum(step.astype(jnp.float32) / max(warmup, 1), 1.0)
        return w * cos(jnp.maximum(step - warmup, 0))
    return f
