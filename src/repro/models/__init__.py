from .blocks import BlockCfg  # noqa: F401
from .lm import (GroupCfg, LMCfg, lm_cache_spec, lm_decode, lm_forward,  # noqa: F401
                 lm_init, lm_init_cache, lm_loss, lm_prefill, lm_spec,
                 softmax_xent)
from .whisper import (WhisperCfg, whisper_cache_spec, whisper_decode,  # noqa: F401
                      whisper_forward, whisper_init, whisper_init_cache,
                      whisper_loss, whisper_prefill, whisper_spec)
