"""Composable transformer/SSM blocks.

A block = optional sequence *mixer* (GQA attention / MLA / Mamba2-SSD) +
optional cross-attention + optional FFN (dense SwiGLU/GELU or MoE), each
pre-normed with a residual.  Blocks are assembled into *groups* (scanned
cycles) by ``repro.models.lm``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import core
from repro.nn.attention import (AttnCfg, attn_decode, attn_forward, attn_init,
                                attn_spec, init_kv_cache, kv_cache_spec)
from repro.nn.mla import (MLACfg, init_mla_cache, mla_cache_spec, mla_decode,
                          mla_forward, mla_init, mla_spec)
from repro.nn.mlp import MLPCfg, mlp_apply, mlp_init, mlp_spec
from repro.nn.moe import MoECfg, moe_apply, moe_init, moe_spec
from repro.nn.ssm import (SSMCfg, init_ssm_state, ssm_decode, ssm_forward,
                          ssm_init, ssm_spec, ssm_state_spec)
from repro.nn.sharding import batch_spec, constrain


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    d_model: int
    mixer: str = "attn"            # "attn" | "mla" | "ssm" | "none"
    ffn: str = "mlp"               # "mlp" | "moe" | "none"
    norm: str = "rms"              # "rms" | "ln" | "ln_np" (OLMo non-parametric)
    attn: Optional[AttnCfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    mlp: Optional[MLPCfg] = None
    moe: Optional[MoECfg] = None
    cross: Optional[AttnCfg] = None  # cross-attention (enc-dec decoder)
    shared: bool = False             # reuse params across group repeats (Zamba2)


# -- norms -------------------------------------------------------------------

def _norm_init(kind: str, d: int, dtype):
    if kind == "rms":
        return core.rmsnorm_init(d, dtype)
    if kind == "ln":
        return core.layernorm_init(d, dtype=dtype)
    if kind == "ln_np":
        return core.layernorm_init(d, elementwise=False, dtype=dtype)
    raise ValueError(kind)


def _norm_spec(kind: str):
    if kind == "rms":
        return core.rmsnorm_spec()
    if kind == "ln":
        return core.layernorm_spec()
    if kind == "ln_np":
        return core.layernorm_spec(elementwise=False)
    raise ValueError(kind)


def _norm_apply(kind: str, p, x):
    if kind == "rms":
        return core.rmsnorm(p, x)
    return core.layernorm(p, x)


# -- block init / spec -------------------------------------------------------

def block_init(key, cfg: BlockCfg, *, dtype=jnp.float32):
    km, kc, kf = jax.random.split(key, 3)
    p = {}
    if cfg.mixer != "none":
        p["norm1"] = _norm_init(cfg.norm, cfg.d_model, dtype)
    if cfg.mixer == "attn":
        p["mixer"] = attn_init(km, cfg.attn, dtype=dtype)
    elif cfg.mixer == "mla":
        p["mixer"] = mla_init(km, cfg.mla, dtype=dtype)
    elif cfg.mixer == "ssm":
        p["mixer"] = ssm_init(km, cfg.ssm, dtype=dtype)
    if cfg.cross is not None:
        p["norm_cross"] = _norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = attn_init(kc, cfg.cross, dtype=dtype)
    if cfg.ffn != "none":
        p["norm2"] = _norm_init(cfg.norm, cfg.d_model, dtype)
    if cfg.ffn == "mlp":
        p["ffn"] = mlp_init(kf, cfg.mlp, dtype=dtype)
    elif cfg.ffn == "moe":
        p["ffn"] = moe_init(kf, cfg.moe, dtype=dtype)
    return p


def block_spec(cfg: BlockCfg):
    s = {}
    if cfg.mixer != "none":
        s["norm1"] = _norm_spec(cfg.norm)
    if cfg.mixer == "attn":
        s["mixer"] = attn_spec(cfg.attn)
    elif cfg.mixer == "mla":
        s["mixer"] = mla_spec(cfg.mla)
    elif cfg.mixer == "ssm":
        s["mixer"] = ssm_spec(cfg.ssm)
    if cfg.cross is not None:
        s["norm_cross"] = _norm_spec(cfg.norm)
        s["cross"] = attn_spec(cfg.cross)
    if cfg.ffn != "none":
        s["norm2"] = _norm_spec(cfg.norm)
    if cfg.ffn == "mlp":
        s["ffn"] = mlp_spec(cfg.mlp)
    elif cfg.ffn == "moe":
        s["ffn"] = moe_spec(cfg.moe)
    return s


# -- forward (train / full sequence) ----------------------------------------

def block_forward(p, cfg: BlockCfg, x, *, positions=None, enc=None,
                  impl: str = "xla", compute_dtype=jnp.bfloat16):
    """x: (B,L,D) -> (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.mixer == "attn":
        x = x + attn_forward(p["mixer"], cfg.attn,
                             _norm_apply(cfg.norm, p["norm1"], x),
                             positions=positions, impl=impl,
                             compute_dtype=compute_dtype)
    elif cfg.mixer == "mla":
        x = x + mla_forward(p["mixer"], cfg.mla,
                            _norm_apply(cfg.norm, p["norm1"], x),
                            positions=positions, compute_dtype=compute_dtype)
    elif cfg.mixer == "ssm":
        x = x + ssm_forward(p["mixer"], cfg.ssm,
                            _norm_apply(cfg.norm, p["norm1"], x),
                            impl=impl, compute_dtype=compute_dtype)
    if cfg.cross is not None:
        x = x + attn_forward(p["cross"], cfg.cross,
                             _norm_apply(cfg.norm, p["norm_cross"], x),
                             kv_src=enc, compute_dtype=compute_dtype)
    if cfg.ffn == "mlp":
        x = x + mlp_apply(p["ffn"], cfg.mlp,
                          _norm_apply(cfg.norm, p["norm2"], x),
                          compute_dtype=compute_dtype)
    elif cfg.ffn == "moe":
        y, a = moe_apply(p["ffn"], cfg.moe,
                         _norm_apply(cfg.norm, p["norm2"], x),
                         compute_dtype=compute_dtype)
        x = x + y
        aux = aux + a
    x = constrain(x, batch_spec(None, None))
    return x, aux


# -- cache -------------------------------------------------------------------

def block_init_cache(cfg: BlockCfg, B: int, S: int, *, enc_len: int = 0,
                     dtype=jnp.bfloat16):
    c = {}
    if cfg.mixer == "attn":
        c["mixer"] = init_kv_cache(B, S, cfg.attn, dtype)
    elif cfg.mixer == "mla":
        c["mixer"] = init_mla_cache(B, S, cfg.mla, dtype)
    elif cfg.mixer == "ssm":
        c["mixer"] = init_ssm_state(B, cfg.ssm, dtype)
    if cfg.cross is not None:
        c["cross"] = init_kv_cache(B, enc_len, cfg.cross, dtype)
    return c


def block_cache_spec(cfg: BlockCfg, *, seq_shard: Optional[str] = None):
    """seq_shard: mesh axis to shard the cache *sequence* dim over (used when
    kv-heads cannot fill the model axis, e.g. long-context decode)."""
    c = {}
    if cfg.mixer == "attn":
        if seq_shard is not None:
            c["mixer"] = {"k": batch_spec(seq_shard, None, None),
                          "v": batch_spec(seq_shard, None, None)}
        else:
            c["mixer"] = kv_cache_spec(cfg.attn)
    elif cfg.mixer == "mla":
        c["mixer"] = mla_cache_spec(cfg.mla)
    elif cfg.mixer == "ssm":
        c["mixer"] = ssm_state_spec(cfg.ssm)
    if cfg.cross is not None:
        c["cross"] = kv_cache_spec(cfg.cross)
    return c


def block_prefill(p, cfg: BlockCfg, x, cache, *, positions=None, enc=None,
                  impl: str = "xla", compute_dtype=jnp.bfloat16):
    """Full-sequence forward that also fills the cache at positions [0, L)."""
    aux = jnp.float32(0.0)
    new = dict(cache)
    if cfg.mixer == "attn":
        y, (k, v) = attn_forward(p["mixer"], cfg.attn,
                                 _norm_apply(cfg.norm, p["norm1"], x),
                                 positions=positions, impl=impl,
                                 compute_dtype=compute_dtype, return_kv=True)
        x = x + y
        new["mixer"] = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["mixer"]["k"], k.astype(cache["mixer"]["k"].dtype), 0, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["mixer"]["v"], v.astype(cache["mixer"]["v"].dtype), 0, axis=1),
        }
    elif cfg.mixer == "mla":
        y, (c_kv, k_rope) = mla_forward(p["mixer"], cfg.mla,
                                        _norm_apply(cfg.norm, p["norm1"], x),
                                        positions=positions,
                                        compute_dtype=compute_dtype,
                                        return_kv=True)
        x = x + y
        new["mixer"] = {
            "c_kv": jax.lax.dynamic_update_slice_in_dim(
                cache["mixer"]["c_kv"],
                c_kv.astype(cache["mixer"]["c_kv"].dtype), 0, axis=1),
            "k_rope": jax.lax.dynamic_update_slice_in_dim(
                cache["mixer"]["k_rope"],
                k_rope.astype(cache["mixer"]["k_rope"].dtype), 0, axis=1),
        }
    elif cfg.mixer == "ssm":
        y, st = ssm_forward(p["mixer"], cfg.ssm,
                            _norm_apply(cfg.norm, p["norm1"], x),
                            impl=impl, compute_dtype=compute_dtype,
                            return_state=True)
        x = x + y
        new["mixer"] = {"conv": st["conv"].astype(cache["mixer"]["conv"].dtype),
                        "ssm": st["ssm"]}
    if cfg.cross is not None:
        y, (k, v) = attn_forward(p["cross"], cfg.cross,
                                 _norm_apply(cfg.norm, p["norm_cross"], x),
                                 kv_src=enc, compute_dtype=compute_dtype,
                                 return_kv=True)
        x = x + y
        new["cross"] = {"k": k.astype(cache["cross"]["k"].dtype),
                        "v": v.astype(cache["cross"]["v"].dtype)}
    if cfg.ffn == "mlp":
        x = x + mlp_apply(p["ffn"], cfg.mlp,
                          _norm_apply(cfg.norm, p["norm2"], x),
                          compute_dtype=compute_dtype)
    elif cfg.ffn == "moe":
        y, a = moe_apply(p["ffn"], cfg.moe,
                         _norm_apply(cfg.norm, p["norm2"], x),
                         compute_dtype=compute_dtype)
        x = x + y
        aux = aux + a
    x = constrain(x, batch_spec(None, None))
    return x, new, aux


def block_decode(p, cfg: BlockCfg, x, cache, pos, *,
                 compute_dtype=jnp.bfloat16):
    """One-token step.  x: (B,1,D); pos: scalar int32."""
    new = dict(cache)
    if cfg.mixer == "attn":
        y, new["mixer"] = attn_decode(p["mixer"], cfg.attn,
                                      _norm_apply(cfg.norm, p["norm1"], x),
                                      cache["mixer"], pos,
                                      compute_dtype=compute_dtype)
        x = x + y
    elif cfg.mixer == "mla":
        y, new["mixer"] = mla_decode(p["mixer"], cfg.mla,
                                     _norm_apply(cfg.norm, p["norm1"], x),
                                     cache["mixer"], pos,
                                     compute_dtype=compute_dtype)
        x = x + y
    elif cfg.mixer == "ssm":
        y, new["mixer"] = ssm_decode(p["mixer"], cfg.ssm,
                                     _norm_apply(cfg.norm, p["norm1"], x),
                                     cache["mixer"],
                                     compute_dtype=compute_dtype)
        x = x + y
    if cfg.cross is not None:
        y, _ = attn_decode(p["cross"], cfg.cross,
                           _norm_apply(cfg.norm, p["norm_cross"], x),
                           cache["cross"], pos, compute_dtype=compute_dtype)
        x = x + y
    if cfg.ffn == "mlp":
        x = x + mlp_apply(p["ffn"], cfg.mlp,
                          _norm_apply(cfg.norm, p["norm2"], x),
                          compute_dtype=compute_dtype)
    elif cfg.ffn == "moe":
        y, _ = moe_apply(p["ffn"], cfg.moe,
                         _norm_apply(cfg.norm, p["norm2"], x),
                         compute_dtype=compute_dtype)
        x = x + y
    return x, new
