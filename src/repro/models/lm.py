"""CompositeLM: a decoder-only LM assembled from *groups* of scanned block
cycles.  Covers dense / MoE / SSM / hybrid / VLM architectures.

A group is ``repeats`` × ``cycle`` (a tuple of heterogeneous BlockCfg).  The
repeats are executed with ``lax.scan`` over stacked parameters, keeping the
HLO (and compile time) independent of depth; blocks marked ``shared=True``
store one copy of parameters reused by every repeat (Zamba2's shared
attention), while their caches remain per-repeat.

VLM support: ``prefix_embed_dim > 0`` adds a projector that maps precomputed
vision-patch embeddings (the stubbed ViT frontend, per the carve-out) into
``n_prefix`` leading sequence slots.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import core
from repro.nn.sharding import batch_spec, constrain
from .blocks import (BlockCfg, block_cache_spec, block_decode, block_forward,
                     block_init, block_init_cache, block_prefill, block_spec)


@dataclasses.dataclass(frozen=True)
class GroupCfg:
    cycle: Tuple[BlockCfg, ...]
    repeats: int


@dataclasses.dataclass(frozen=True)
class LMCfg:
    name: str
    vocab: int
    d_model: int
    groups: Tuple[GroupCfg, ...]
    final_norm: str = "rms"
    tie_embeddings: bool = True
    pos_embed: str = "none"        # "none" (rope inside attn) | "learned"
    max_positions: int = 0          # for learned positions
    n_prefix: int = 0               # VLM: number of vision-patch slots
    prefix_embed_dim: int = 0       # VLM: raw patch-embedding dim (0 = no VLM)
    mtp: bool = False               # DeepSeek-V3 multi-token prediction module
    remat: bool = False             # checkpoint each scanned cycle
    unroll: bool = False            # python-unroll group repeats instead of
    # lax.scan — used by the dry-run so XLA cost_analysis counts every layer
    # (while-loop bodies are NOT multiplied by trip count), at the price of
    # depth-proportional HLO/compile time.

    @property
    def n_layers(self) -> int:
        return sum(g.repeats * len(g.cycle) for g in self.groups)


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def _stack_spec(spec):
    """Prepend a None (repeat) dim to every PartitionSpec leaf."""
    return jax.tree.map(lambda s: P(None, *s), spec,
                        is_leaf=lambda s: isinstance(s, P))


def _group_init(key, g: GroupCfg, *, dtype):
    shared, stacked = {}, {}
    keys = jax.random.split(key, len(g.cycle))
    for i, bcfg in enumerate(g.cycle):
        if bcfg.shared:
            shared[str(i)] = block_init(keys[i], bcfg, dtype=dtype)
        else:
            bkeys = jax.random.split(keys[i], g.repeats)
            stacked[str(i)] = jax.vmap(
                lambda k, c=bcfg: block_init(k, c, dtype=dtype))(bkeys)
    return {"shared": shared, "stacked": stacked}


def _group_spec(g: GroupCfg):
    shared, stacked = {}, {}
    for i, bcfg in enumerate(g.cycle):
        if bcfg.shared:
            shared[str(i)] = block_spec(bcfg)
        else:
            stacked[str(i)] = _stack_spec(block_spec(bcfg))
    return {"shared": shared, "stacked": stacked}


def lm_init(key, cfg: LMCfg, *, dtype=jnp.float32):
    keys = jax.random.split(key, len(cfg.groups) + 4)
    p: dict = {
        "embed": core.embedding_init(keys[0], cfg.vocab, cfg.d_model, dtype=dtype),
        "groups": [
            _group_init(keys[2 + i], g, dtype=dtype)
            for i, g in enumerate(cfg.groups)
        ],
        "final_norm": (core.rmsnorm_init(cfg.d_model, dtype)
                       if cfg.final_norm == "rms"
                       else core.layernorm_init(
                           cfg.d_model, elementwise=cfg.final_norm == "ln",
                           dtype=dtype)),
    }
    if cfg.pos_embed == "learned":
        p["pos"] = core.normal_init(keys[1], (cfg.max_positions, cfg.d_model),
                                    0.02, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = core.linear_init(keys[-1], cfg.d_model, cfg.vocab,
                                        dtype=dtype)
    if cfg.prefix_embed_dim:
        p["proj"] = core.linear_init(keys[-2], cfg.prefix_embed_dim,
                                     cfg.d_model, bias=True, dtype=dtype)
    if cfg.mtp:
        # DeepSeek-V3 MTP depth-1 module: norm both streams, project 2d->d,
        # one extra block, shared unembed.
        km1, km2 = jax.random.split(keys[-3])
        mtp_block = cfg.groups[-1].cycle[-1]
        p["mtp"] = {
            "norm_h": core.rmsnorm_init(cfg.d_model, dtype),
            "norm_e": core.rmsnorm_init(cfg.d_model, dtype),
            "proj": core.linear_init(km1, 2 * cfg.d_model, cfg.d_model,
                                     dtype=dtype),
            "block": block_init(km2, mtp_block, dtype=dtype),
        }
    return p


def lm_spec(cfg: LMCfg):
    s: dict = {
        "embed": core.embedding_spec(),
        "groups": [_group_spec(g) for g in cfg.groups],
        "final_norm": (core.rmsnorm_spec() if cfg.final_norm == "rms"
                       else core.layernorm_spec(
                           elementwise=cfg.final_norm == "ln")),
    }
    if cfg.pos_embed == "learned":
        s["pos"] = P(None, None)
    if not cfg.tie_embeddings:
        s["lm_head"] = {"w": P(None, "model")}
    if cfg.prefix_embed_dim:
        s["proj"] = {"w": P(None, None), "b": P(None)}
    if cfg.mtp:
        mtp_block = cfg.groups[-1].cycle[-1]
        s["mtp"] = {
            "norm_h": core.rmsnorm_spec(),
            "norm_e": core.rmsnorm_spec(),
            "proj": {"w": P(None, None)},
            "block": block_spec(mtp_block),
        }
    return s


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_inputs(p, cfg: LMCfg, tokens, prefix_embeds, *, compute_dtype,
                  pos_offset: int = 0):
    x = core.embed(p["embed"], tokens, compute_dtype=compute_dtype)
    if cfg.prefix_embed_dim and prefix_embeds is not None:
        vis = core.linear(p["proj"], prefix_embeds, compute_dtype=compute_dtype)
        x = jnp.concatenate([vis, x], axis=1)
    if cfg.pos_embed == "learned":
        L = x.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(p["pos"], pos_offset, L, axis=0)
        x = x + pos.astype(compute_dtype)
    return x


def _logits(p, cfg: LMCfg, x, *, compute_dtype):
    if cfg.tie_embeddings:
        logits = core.unembed(p["embed"], x, compute_dtype=compute_dtype)
    else:
        w = p["lm_head"]["w"].astype(compute_dtype)
        logits = jnp.einsum("...d,dv->...v", x.astype(compute_dtype), w,
                            preferred_element_type=jnp.float32)
    return constrain(logits, batch_spec(None, "model"))


def _final_norm(p, cfg: LMCfg, x):
    if cfg.final_norm == "rms":
        return core.rmsnorm(p["final_norm"], x)
    return core.layernorm(p["final_norm"], x)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _index_tree(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _group_forward(gp, g: GroupCfg, x, *, positions, impl, compute_dtype,
                   remat, unroll=False):
    def body(carry, xs):
        x, aux = carry
        for i, bcfg in enumerate(g.cycle):
            bp = gp["shared"][str(i)] if bcfg.shared else xs[str(i)]
            x, a = block_forward(bp, bcfg, x, positions=positions, impl=impl,
                                 compute_dtype=compute_dtype)
            aux = aux + a
        return (x, aux), None

    if remat:
        body = jax.checkpoint(body)
    if unroll:
        carry = (x, jnp.float32(0.0))
        for r in range(g.repeats):
            xs_r = (_index_tree(gp["stacked"], r) if gp["stacked"] else None)
            carry, _ = body(carry, xs_r)
        return carry
    xs = gp["stacked"] if gp["stacked"] else None
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs,
                               length=g.repeats)
    return x, aux


def lm_forward(p, cfg: LMCfg, tokens, *, prefix_embeds=None, positions=None,
               impl: str = "xla", compute_dtype=jnp.bfloat16):
    """tokens: (B, L_text) int32 [+ prefix_embeds (B, n_prefix, raw_dim)].

    Returns (logits (B, L, vocab) f32, aux_loss scalar)."""
    x = _embed_inputs(p, cfg, tokens, prefix_embeds,
                      compute_dtype=compute_dtype)
    L = x.shape[1]
    if positions is None:
        positions = jnp.arange(L)
    x = constrain(x, batch_spec(None, None))
    aux = jnp.float32(0.0)
    for gp, g in zip(p["groups"], cfg.groups):
        x, a = _group_forward(gp, g, x, positions=positions, impl=impl,
                              compute_dtype=compute_dtype, remat=cfg.remat,
                              unroll=cfg.unroll)
        aux = aux + a
    x = _final_norm(p, cfg, x)
    return _logits(p, cfg, x, compute_dtype=compute_dtype), aux


def softmax_xent(logits, labels, *, ignore: int = -100):
    """logits (B,L,V) f32; labels (B,L) int32 with `ignore` masked out."""
    mask = (labels != ignore)
    labels_safe = jnp.where(mask, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1)


def lm_loss(p, cfg: LMCfg, batch, *, impl: str = "xla",
            compute_dtype=jnp.bfloat16):
    """batch: {"tokens", "labels"[, "prefix_embeds"]}.  Returns (loss, metrics).

    With ``cfg.mtp`` the DeepSeek-V3 depth-1 MTP loss is added (weight 0.3)."""
    logits, aux = lm_forward(p, cfg, batch["tokens"],
                             prefix_embeds=batch.get("prefix_embeds"),
                             impl=impl, compute_dtype=compute_dtype)
    loss = softmax_xent(logits, batch["labels"])
    metrics = {"xent": loss, "aux": aux}
    if cfg.mtp:
        # depth-1 MTP: combine hidden h_{1:L-1} with embedding of t_{2:L}
        # (approximated from the token stream), one extra block, shared head.
        x = _embed_inputs(p, cfg, batch["tokens"], batch.get("prefix_embeds"),
                          compute_dtype=compute_dtype)
        h = core.rmsnorm(p["mtp"]["norm_h"], x[:, :-1])
        e = core.rmsnorm(p["mtp"]["norm_e"], x[:, 1:])
        hm = core.linear(p["mtp"]["proj"], jnp.concatenate([h, e], axis=-1),
                         compute_dtype=compute_dtype)
        mtp_block = cfg.groups[-1].cycle[-1]
        hm, a2 = block_forward(p["mtp"]["block"], mtp_block, hm,
                               positions=jnp.arange(hm.shape[1]),
                               compute_dtype=compute_dtype)
        mtp_logits = _logits(p, cfg, _final_norm(p, cfg, hm),
                             compute_dtype=compute_dtype)
        mtp_loss = softmax_xent(mtp_logits, batch["labels"][:, 1:])
        loss = loss + 0.3 * mtp_loss
        aux = aux + a2
        metrics["mtp_xent"] = mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------

def _stacked_cache(g: GroupCfg, make):
    """Per-repeat cache for every stateful block in the cycle."""
    out = {}
    for i, bcfg in enumerate(g.cycle):
        c = make(bcfg)
        if c:
            out[str(i)] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (g.repeats,) + a.shape)
                if hasattr(a, "shape") else a, c)
    return out


def lm_init_cache(cfg: LMCfg, B: int, S: int, *, dtype=jnp.bfloat16):
    return [
        _stacked_cache(g, lambda b: block_init_cache(b, B, S, dtype=dtype))
        for g in cfg.groups
    ]


def lm_cache_spec(cfg: LMCfg, *, seq_shard: Optional[str] = None):
    out = []
    for g in cfg.groups:
        gs = {}
        for i, bcfg in enumerate(g.cycle):
            c = block_cache_spec(bcfg, seq_shard=seq_shard)
            if c:
                gs[str(i)] = _stack_spec(c)
        out.append(gs)
    return out


def _group_prefill(gp, g: GroupCfg, x, cache, *, positions, impl,
                   compute_dtype, unroll=False):
    def body(carry, xs):
        x, aux = carry
        params_xs, cache_xs = xs
        new_cache = {}
        for i, bcfg in enumerate(g.cycle):
            bp = gp["shared"][str(i)] if bcfg.shared else params_xs[str(i)]
            bc = cache_xs.get(str(i), {})
            x, nc, a = block_prefill(bp, bcfg, x, bc, positions=positions,
                                     impl=impl, compute_dtype=compute_dtype)
            if nc:
                new_cache[str(i)] = nc
            aux = aux + a
        return (x, aux), new_cache

    if unroll:
        carry = (x, jnp.float32(0.0))
        ys = []
        for r in range(g.repeats):
            carry, nc = body(carry, (_index_tree(gp["stacked"], r),
                                     _index_tree(cache, r)))
            ys.append(nc)
        (x, aux) = carry
        return x, _stack_trees(ys), aux
    xs = (gp["stacked"], cache)
    (x, aux), new_cache = jax.lax.scan(body, (x, jnp.float32(0.0)), xs,
                                       length=g.repeats)
    return x, new_cache, aux


def lm_prefill(p, cfg: LMCfg, tokens, cache, *, prefix_embeds=None,
               impl: str = "xla", compute_dtype=jnp.bfloat16):
    """Prefill positions [0, L); returns (last-token logits, filled cache)."""
    x = _embed_inputs(p, cfg, tokens, prefix_embeds,
                      compute_dtype=compute_dtype)
    L = x.shape[1]
    positions = jnp.arange(L)
    x = constrain(x, batch_spec(None, None))
    new_cache = []
    for gp, g, gc in zip(p["groups"], cfg.groups, cache):
        x, nc, _ = _group_prefill(gp, g, x, gc, positions=positions,
                                  impl=impl, compute_dtype=compute_dtype,
                                  unroll=cfg.unroll)
        new_cache.append(nc)
    x = _final_norm(p, cfg, x[:, -1:])
    return _logits(p, cfg, x, compute_dtype=compute_dtype), new_cache


def _group_decode(gp, g: GroupCfg, x, cache, pos, *, compute_dtype,
                  unroll=False):
    def body(x, xs):
        params_xs, cache_xs = xs
        new_cache = {}
        for i, bcfg in enumerate(g.cycle):
            bp = gp["shared"][str(i)] if bcfg.shared else params_xs[str(i)]
            bc = cache_xs.get(str(i), {})
            x, nc = block_decode(bp, bcfg, x, bc, pos,
                                 compute_dtype=compute_dtype)
            if nc:
                new_cache[str(i)] = nc
        return x, new_cache

    if unroll:
        ys = []
        for r in range(g.repeats):
            x, nc = body(x, (_index_tree(gp["stacked"], r),
                             _index_tree(cache, r)))
            ys.append(nc)
        return x, _stack_trees(ys)
    x, new_cache = jax.lax.scan(body, x, (gp["stacked"], cache),
                                length=g.repeats)
    return x, new_cache


def lm_decode(p, cfg: LMCfg, token, cache, pos, *,
              compute_dtype=jnp.bfloat16):
    """One-token decode.  token: (B, 1) int32; pos: scalar int32 (absolute
    position of `token`).  Returns (logits (B,1,V), new_cache)."""
    x = core.embed(p["embed"], token, compute_dtype=compute_dtype)
    if cfg.pos_embed == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            p["pos"], pos, 1, axis=0).astype(compute_dtype)
    x = constrain(x, batch_spec(None, None))
    new_cache = []
    for gp, g, gc in zip(p["groups"], cfg.groups, cache):
        x, nc = _group_decode(gp, g, x, gc, pos, compute_dtype=compute_dtype,
                              unroll=cfg.unroll)
        new_cache.append(nc)
    x = _final_norm(p, cfg, x)
    return _logits(p, cfg, x, compute_dtype=compute_dtype), new_cache
