"""Whisper-style encoder-decoder transformer backbone.

Per the assignment carve-out, the mel-spectrogram + conv feature extractor is
STUBBED: the encoder consumes precomputed frame embeddings
``(B, n_frames, d_model)`` provided by ``input_specs()``.  Everything after
that — sinusoidal encoder positions, encoder self-attention stack, decoder
with learned positions, causal self-attention, cross-attention and tied
unembedding — is implemented for real.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import core
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MLPCfg
from repro.nn.sharding import batch_spec, constrain
from .blocks import BlockCfg, block_forward, block_init, block_spec
from .lm import (GroupCfg, LMCfg, _group_decode, _group_forward,
                 _group_init, _group_prefill, _group_spec, _stack_spec,
                 _stacked_cache, softmax_xent)
from . import lm as lm_mod
from .blocks import block_cache_spec, block_init_cache


@dataclasses.dataclass(frozen=True)
class WhisperCfg:
    name: str
    vocab: int
    d_model: int
    n_layers: int          # per stack (encoder and decoder)
    n_heads: int
    d_ff: int
    n_frames: int = 1500   # encoder positions (stubbed conv output length)
    max_positions: int = 4096  # decoder learned positions (paper: 448; we
                               # extend the table to cover the assigned shapes)
    remat: bool = False
    unroll: bool = False       # python-unroll layer stacks (see LMCfg.unroll)

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def enc_block(self) -> BlockCfg:
        return BlockCfg(
            d_model=self.d_model, mixer="attn", ffn="mlp", norm="ln",
            attn=AttnCfg(self.d_model, self.n_heads, self.n_heads,
                         self.d_head, rope=False, causal=False),
            mlp=MLPCfg(self.d_model, self.d_ff, gated=False, act="gelu"))

    def dec_block(self) -> BlockCfg:
        return BlockCfg(
            d_model=self.d_model, mixer="attn", ffn="mlp", norm="ln",
            attn=AttnCfg(self.d_model, self.n_heads, self.n_heads,
                         self.d_head, rope=False, causal=True),
            cross=AttnCfg(self.d_model, self.n_heads, self.n_heads,
                          self.d_head, rope=False, causal=False, cross=True,
                          d_kv_in=self.d_model),
            mlp=MLPCfg(self.d_model, self.d_ff, gated=False, act="gelu"))

    def enc_group(self) -> GroupCfg:
        return GroupCfg((self.enc_block(),), self.n_layers)

    def dec_group(self) -> GroupCfg:
        return GroupCfg((self.dec_block(),), self.n_layers)


def sinusoids(length: int, d: int) -> jnp.ndarray:
    half = d // 2
    log_timescale = math.log(10000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1)


def whisper_init(key, cfg: WhisperCfg, *, dtype=jnp.float32):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "embed": core.embedding_init(k1, cfg.vocab, cfg.d_model, dtype=dtype),
        "pos": core.normal_init(k2, (cfg.max_positions, cfg.d_model), 0.02,
                                dtype),
        "enc": _group_init(k3, cfg.enc_group(), dtype=dtype),
        "enc_norm": core.layernorm_init(cfg.d_model, dtype=dtype),
        "dec": _group_init(k4, cfg.dec_group(), dtype=dtype),
        "dec_norm": core.layernorm_init(cfg.d_model, dtype=dtype),
    }


def whisper_spec(cfg: WhisperCfg):
    return {
        "embed": core.embedding_spec(),
        "pos": P(None, None),
        "enc": _group_spec(cfg.enc_group()),
        "enc_norm": core.layernorm_spec(),
        "dec": _group_spec(cfg.dec_group()),
        "dec_norm": core.layernorm_spec(),
    }


def whisper_encode(p, cfg: WhisperCfg, frame_embeds, *,
                   compute_dtype=jnp.bfloat16):
    """frame_embeds: (B, n_frames, d_model) — stubbed conv frontend output."""
    x = frame_embeds.astype(compute_dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(compute_dtype)
    x = constrain(x, batch_spec(None, None))
    x, _ = _group_forward(p["enc"], cfg.enc_group(), x,
                          positions=jnp.arange(x.shape[1]), impl="xla",
                          compute_dtype=compute_dtype, remat=cfg.remat,
                          unroll=cfg.unroll)
    return core.layernorm(p["enc_norm"], x)


def _decode_embed(p, cfg: WhisperCfg, tokens, pos_offset, compute_dtype):
    x = core.embed(p["embed"], tokens, compute_dtype=compute_dtype)
    L = tokens.shape[1]
    pos = jax.lax.dynamic_slice_in_dim(p["pos"], pos_offset, L, axis=0)
    return x + pos.astype(compute_dtype)


def whisper_forward(p, cfg: WhisperCfg, frame_embeds, tokens, *,
                    compute_dtype=jnp.bfloat16):
    """Teacher-forced training forward.  Returns (logits, aux=0)."""
    enc = whisper_encode(p, cfg, frame_embeds, compute_dtype=compute_dtype)
    x = _decode_embed(p, cfg, tokens, 0, compute_dtype)
    x = constrain(x, batch_spec(None, None))
    # cross-attention needs `enc` — thread through a closure-specialised group
    g = cfg.dec_group()

    def body(carry, xs):
        x, aux = carry
        x, a = block_forward(xs["0"], g.cycle[0], x,
                             positions=jnp.arange(x.shape[1]), enc=enc,
                             compute_dtype=compute_dtype)
        return (x, aux + a), None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll:
        carry = (x, jnp.float32(0.0))
        for r in range(g.repeats):
            carry, _ = body(carry, lm_mod._index_tree(p["dec"]["stacked"], r))
        x, _ = carry
    else:
        (x, _), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                 p["dec"]["stacked"], length=g.repeats)
    x = core.layernorm(p["dec_norm"], x)
    logits = core.unembed(p["embed"], x, compute_dtype=compute_dtype)
    return constrain(logits, batch_spec(None, "model")), jnp.float32(0.0)


def whisper_loss(p, cfg: WhisperCfg, batch, *, compute_dtype=jnp.bfloat16):
    logits, _ = whisper_forward(p, cfg, batch["frame_embeds"],
                                batch["tokens"], compute_dtype=compute_dtype)
    loss = softmax_xent(logits, batch["labels"])
    return loss, {"loss": loss, "xent": loss}


# -- serving ------------------------------------------------------------------

def whisper_init_cache(cfg: WhisperCfg, B: int, S: int, *,
                       dtype=jnp.bfloat16):
    g = cfg.dec_group()
    return _stacked_cache(
        g, lambda b: block_init_cache(b, B, S, enc_len=cfg.n_frames,
                                      dtype=dtype))


def whisper_cache_spec(cfg: WhisperCfg, *, seq_shard=None):
    g = cfg.dec_group()
    out = {}
    for i, bcfg in enumerate(g.cycle):
        out[str(i)] = _stack_spec(block_cache_spec(bcfg, seq_shard=seq_shard))
    return out


def whisper_prefill(p, cfg: WhisperCfg, frame_embeds, tokens, cache, *,
                    compute_dtype=jnp.bfloat16):
    """Encode audio + prefill decoder tokens [0, L).  Returns
    (last-token logits, cache) — cross-attention K/V are (re)computed from the
    encoder output and stored in the cache."""
    enc = whisper_encode(p, cfg, frame_embeds, compute_dtype=compute_dtype)
    x = _decode_embed(p, cfg, tokens, 0, compute_dtype)
    x = constrain(x, batch_spec(None, None))
    g = cfg.dec_group()
    from .blocks import block_prefill

    def body(carry, xs):
        x, _ = carry
        params_xs, cache_xs = xs
        x, nc, _ = block_prefill(params_xs["0"], g.cycle[0], x, cache_xs["0"],
                                 positions=jnp.arange(x.shape[1]), enc=enc,
                                 compute_dtype=compute_dtype)
        return (x, jnp.float32(0.0)), {"0": nc}

    if cfg.unroll:
        carry = (x, jnp.float32(0.0))
        ys = []
        for r in range(g.repeats):
            carry, nc = body(carry, (lm_mod._index_tree(p["dec"]["stacked"], r),
                                     lm_mod._index_tree(cache, r)))
            ys.append(nc)
        x, _ = carry
        new_cache = lm_mod._stack_trees(ys)
    else:
        (x, _), new_cache = jax.lax.scan(
            body, (x, jnp.float32(0.0)), (p["dec"]["stacked"], cache),
            length=g.repeats)
    x = core.layernorm(p["dec_norm"], x[:, -1:])
    logits = core.unembed(p["embed"], x, compute_dtype=compute_dtype)
    return logits, new_cache


def whisper_decode(p, cfg: WhisperCfg, token, cache, pos, *,
                   compute_dtype=jnp.bfloat16):
    """One decoder token against self- and cross-attention caches."""
    x = _decode_embed(p, cfg, token, pos, compute_dtype)
    x = constrain(x, batch_spec(None, None))
    g = cfg.dec_group()
    x, new_cache = _group_decode(p["dec"], g, x, cache, pos,
                                 compute_dtype=compute_dtype,
                                 unroll=cfg.unroll)
    x = core.layernorm(p["dec_norm"], x)
    logits = core.unembed(p["embed"], x, compute_dtype=compute_dtype)
    return logits, new_cache
