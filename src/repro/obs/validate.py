"""CLI schema validator for repro-obs JSONL run logs.

Usage::

    PYTHONPATH=src python -m repro.obs.validate run.jsonl [more.jsonl ...]

Exits non-zero (with the offending file:line) on the first invalid
record; prints a per-file record count otherwise.  CI runs this over the
telemetry-on smoke log.
"""
from __future__ import annotations

import argparse
import sys

from .writer import validate_jsonl


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate repro-obs JSONL run logs against the schema.")
    ap.add_argument("paths", nargs="+", help="JSONL run logs to validate")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.paths:
        try:
            n = validate_jsonl(path)
        except (OSError, ValueError) as e:
            print(f"FAIL {e}", file=sys.stderr)
            rc = 1
        else:
            print(f"ok {path}: {n} records")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
