"""Jit/scan-safe telemetry: in-scan taps, JSONL emission, profiling hooks.

See DESIGN.md §15 for the telemetry contract (tap points, schema version,
off-by-default guarantee).
"""
from .profiling import (compile_count, compile_events, profiler_trace,
                        record_compile, reset_compiles, stage)
from .taps import ObsCfg, broadcast_diag, combine_updates, reduce_update_diag
from .writer import (SCHEMA, MetricWriter, cfg_hash, progress_line,
                     run_manifest, to_jsonable, validate_jsonl,
                     validate_record)

__all__ = [
    "ObsCfg", "broadcast_diag", "combine_updates", "reduce_update_diag",
    "SCHEMA", "MetricWriter", "cfg_hash", "progress_line", "run_manifest",
    "to_jsonable", "validate_jsonl", "validate_record",
    "compile_count", "compile_events", "record_compile", "reset_compiles",
    "stage", "profiler_trace",
]
