"""In-scan metric taps (DESIGN.md §15): jit/scan-safe learner diagnostics.

A *tap* is an extra scan output carried alongside the training stats —
per-update learner diagnostics (TD errors, Q values, gradient norms,
denoising magnitudes) accumulated INSIDE the episode scans with no host
callbacks.  Taps are gated by the static :class:`ObsCfg` carried on
``T2DRLCfg``: with ``enabled=False`` (the default) every tap site is a
python-level no-op and the episode cores trace the exact pre-telemetry
program, so telemetry-off stays bit-identical to the prior build.

The update scans gate learner steps behind ``lax.cond`` (warmup, buffer
fill), so a tapped slot emits either the update's metric pytree or a
matching zeros pytree (the agent's ``diag_zero``) plus a 0/1 ``did``
flag.  :func:`reduce_update_diag` then collapses the per-slot streams to
episode-level statistics — did-weighted means, masked maxima for keys
ending in ``_max``, and the update count — under flat ``"diag/..."`` keys
that ride the ordinary history dict.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ObsCfg:
    """Static telemetry configuration (hashable — jit-static via T2DRLCfg).

    Attributes
    ----------
    enabled : bool
        Master switch.  ``False`` (default) keeps every tap site a
        python-level no-op: the episode cores compile the exact
        pre-telemetry program (the off-by-default guarantee of
        DESIGN.md §15).
    learner : bool
        Per-update learner diagnostics — DDQN TD-error stats / Q values /
        target-net divergence, D3PG critic loss / gradient norms /
        per-step denoising magnitudes — accumulated inside the update
        scans and reduced to per-episode ``"diag/..."`` history keys.
    replay : bool
        Replay-buffer occupancy (size and fill fraction of the slot and
        frame buffers) at episode end.

    Host-side concerns (file paths, writers) intentionally do NOT live
    here: this object is hashed into the jit cache key, so it must carry
    only trace-relevant switches.
    """
    enabled: bool = False
    learner: bool = True
    replay: bool = True

    @property
    def learner_on(self) -> bool:
        return self.enabled and self.learner

    @property
    def replay_on(self) -> bool:
        return self.enabled and self.replay


def combine_updates(ms):
    """Collapse the ``(N, ...)`` metric stream of an inner
    ``updates_per_slot`` scan to one per-slot pytree: mean over the update
    axis, except ``*_max`` keys which take the max (every inner update ran
    unconditionally, so no ``did`` weighting is needed)."""
    return {k: (jnp.max(v, axis=0) if k.endswith("_max")
                else jnp.mean(v, axis=0))
            for k, v in ms.items()}


def reduce_update_diag(ms, did, prefix: str = "diag/"):
    """Episode-level reduction of a tapped update stream.

    ``ms`` is a flat dict of stacked per-slot metrics whose leaves carry
    the scan axes first (e.g. ``(T, K)`` scalars or ``(T, K, B)`` /
    ``(T, K, B, L)`` batched leaves); ``did`` is the matching 0/1
    did-an-update flag of shape exactly the scan axes.  Returns flat
    ``{prefix+k: value}`` entries: the did-weighted mean over the scan
    axes per key (zero when no update ran), a did-masked max for keys
    ending ``_max``, plus ``prefix+"updates"`` — the update count."""
    did = jnp.asarray(did, jnp.float32)
    axes = tuple(range(did.ndim))
    n = jnp.sum(did)
    out = {}
    for k, v in ms.items():
        w = did.reshape(did.shape + (1,) * (v.ndim - did.ndim))
        if k.endswith("_max"):
            masked = jnp.where(w > 0, v, -jnp.inf)
            val = jnp.where(n > 0, jnp.max(masked, axis=axes), 0.0)
        else:
            val = jnp.sum(v * w, axis=axes) / jnp.maximum(n, 1.0)
        out[prefix + k] = val
    out[prefix + "updates"] = n
    return out


def broadcast_diag(diag_zero, B: int):
    """Stack a single-learner ``diag_zero`` pytree to B learners (the
    fused-core zeros branch of the update ``lax.cond``)."""
    return jax.tree.map(lambda x: jnp.zeros((B,) + jnp.shape(x),
                                            jnp.asarray(x).dtype), diag_zero)
