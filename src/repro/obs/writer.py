"""Structured emission (DESIGN.md §15): schema-versioned JSONL + manifests.

Every training / evaluation / fleet entry point can be handed a
:class:`MetricWriter`; records are append-only JSON objects, one per line,
stamped ``{"schema": "repro-obs/1", "kind": <kind>, ...}`` and validated
against the per-kind required-field table at write time — schema drift
fails at the producer, not in a downstream parser.  A run log always
starts with a ``manifest`` record (:func:`run_manifest`: config hash,
seed, git sha, jax/device info), the contract :func:`validate_jsonl`
enforces (CLI: ``python -m repro.obs.validate``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import subprocess
import sys
import time

import numpy as np

SCHEMA = "repro-obs/1"

# Required fields per record kind (beyond "schema"/"kind").  Extra fields
# are always allowed — the schema pins the floor, not the ceiling.
REQUIRED_FIELDS = {
    "manifest": ("run_id", "created_unix", "jax", "backend", "device_kind",
                 "cfg_hash"),
    "train_chunk": ("episode", "episodes", "wall_s", "stats"),
    "eval": ("metrics",),
    "fleet_frame": ("frame", "p50_s", "p95_s", "p99_s", "drop_rate",
                    "slo_viol_rate", "mean_backlog_s"),
    "fleet_summary": ("metrics",),
    "profile": ("stage", "wall_s"),
}


def _jsonable(x):
    """Map arrays / np scalars / dataclasses to plain JSON values."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (str, bool, int, float)) or x is None:
        return x
    if isinstance(x, (np.bool_,)):
        return bool(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    if hasattr(x, "tolist"):            # np / jnp arrays (and 0-d scalars)
        return _jsonable(np.asarray(x).tolist())
    if dataclasses.is_dataclass(x) and not isinstance(x, type):
        return repr(x)
    return str(x)


# public name for downstream consumers (benchmarks.common.save_json)
to_jsonable = _jsonable


def cfg_hash(cfg) -> str:
    """Short stable hash of a frozen-dataclass config (its repr includes
    every field, nested configs included)."""
    return hashlib.sha256(repr(cfg).encode()).hexdigest()[:16]


def _git_sha():
    try:
        here = os.path.dirname(os.path.abspath(__file__))
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=here,
                             capture_output=True, text=True, timeout=5)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else None
    except Exception:
        return None


def run_manifest(cfg=None, extra=None) -> dict:
    """The shared run-manifest record (DESIGN.md §15): reproducibility
    context — git sha, jax/jaxlib versions, device kind/count, config
    hash + repr, seed — stamped into every JSONL run log and (via
    ``benchmarks.common.save_json``) every benchmark JSON."""
    import jax                                    # deferred: keep the
    try:                                          # writer importable early
        import jaxlib
        jaxlib_v = getattr(jaxlib, "__version__", None)
    except Exception:
        jaxlib_v = None
    dev = jax.devices()[0]
    rec = {
        "schema": SCHEMA,
        "kind": "manifest",
        "run_id": f"{int(time.time() * 1e3):x}-{os.getpid():x}",
        "created_unix": time.time(),
        "argv": list(sys.argv),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jaxlib": jaxlib_v,
        "backend": jax.default_backend(),
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "cpu_count": os.cpu_count(),
        "git_sha": _git_sha(),
        "cfg_hash": cfg_hash(cfg) if cfg is not None else None,
    }
    if cfg is not None:
        rec["cfg"] = repr(cfg)
        rec["seed"] = getattr(cfg, "seed", None)
    if extra:
        rec.update(_jsonable(extra))
    return rec


def progress_line(episode: int, last: dict) -> str:
    """The human-readable per-chunk progress line (the console sink of the
    structured logger) — byte-identical to the legacy ``train_t2drl``
    print format."""
    return (f"ep {episode:4d} reward {last['episode_reward']:9.2f} "
            f"hit {last['hit_ratio']:.3f} "
            f"G {last['utility']:7.2f}")


def validate_record(rec) -> None:
    """Raise ``ValueError`` unless ``rec`` is a schema-valid record."""
    if not isinstance(rec, dict):
        raise ValueError(f"record must be a JSON object, got {type(rec)}")
    if rec.get("schema") != SCHEMA:
        raise ValueError(f"unknown schema {rec.get('schema')!r}; "
                         f"expected {SCHEMA!r}")
    kind = rec.get("kind")
    if kind not in REQUIRED_FIELDS:
        raise ValueError(f"unknown record kind {kind!r}; expected one of "
                         f"{sorted(REQUIRED_FIELDS)}")
    missing = [f for f in REQUIRED_FIELDS[kind] if f not in rec]
    if missing:
        raise ValueError(f"{kind!r} record is missing required fields "
                         f"{missing}")


def validate_jsonl(path) -> int:
    """Validate a JSONL run log: every line a schema-valid record, the
    first a ``manifest``.  Returns the record count; raises ``ValueError``
    (with the offending line number) on any violation."""
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{lineno}: invalid JSON: {e}")
            try:
                validate_record(rec)
            except ValueError as e:
                raise ValueError(f"{path}:{lineno}: {e}")
            if n == 0 and rec["kind"] != "manifest":
                raise ValueError(f"{path}:{lineno}: first record must be a "
                                 f"manifest, got {rec['kind']!r}")
            n += 1
    if n == 0:
        raise ValueError(f"{path}: empty run log")
    return n


class MetricWriter:
    """Append-only schema-versioned JSONL sink.

    Records are validated at write time and flushed per line (crash-safe
    logs).  ``ensure_manifest`` makes "manifest first" idempotent across
    nested callers — e.g. a benchmark opens the writer and stamps the
    manifest, then hands it to ``train_t2drl``, whose own
    ``ensure_manifest`` becomes a no-op."""

    def __init__(self, path, *, mode: str = "w"):
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self.path = path
        self._f = open(path, mode)
        self._wrote_manifest = False

    def write(self, kind: str, **fields) -> dict:
        rec = {"schema": SCHEMA, "kind": kind}
        rec.update(_jsonable(fields))
        validate_record(rec)
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()
        return rec

    def manifest(self, cfg=None, extra=None) -> dict:
        rec = run_manifest(cfg=cfg, extra=extra)
        validate_record(rec)
        self._f.write(json.dumps(_jsonable(rec)) + "\n")
        self._f.flush()
        self._wrote_manifest = True
        return rec

    def ensure_manifest(self, cfg=None, extra=None):
        if not self._wrote_manifest:
            self.manifest(cfg=cfg, extra=extra)

    def close(self):
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
