"""Profiling hooks (DESIGN.md §15): stage timers and a recompile counter.

The recompile counter is fed by the episode-dispatch layer in
``core.t2drl`` — every fresh XLA compile registers a :func:`record_compile`
event, so silent retraces (a ragged final ``log_every`` chunk, a config
leaking a traced value into a static field) show up as a count, not a
mystery slowdown.  :func:`stage` wraps host-side phases in wall-clock
timers (emitting ``profile`` records through a ``MetricWriter`` when one
is attached), and :func:`profiler_trace` gates a ``jax.profiler`` trace
behind an opt-in flag for the benchmarks.
"""
from __future__ import annotations

import contextlib
import time
import warnings

# Compile-event log: (tag, signature) per fresh XLA compile, appended by
# core.t2drl's episode dispatch.  Module-global on purpose — it must be
# shared across jit caches and readable from tests.
_COMPILE_EVENTS: list = []
_WARNED_TAGS: set = set()


def record_compile(tag: str, signature: str = "") -> None:
    """Register one fresh compile of the program named ``tag``."""
    _COMPILE_EVENTS.append((tag, signature))
    sigs = {s for t, s in _COMPILE_EVENTS if t == tag}
    if len(sigs) > 2 and tag not in _WARNED_TAGS:
        # two programs per tag are expected for chunked training (full
        # chunk + remainder); a third signature means a silent retrace —
        # or a caller legitimately reusing one config at several batch
        # shapes, so warn once per tag, not per extra program
        _WARNED_TAGS.add(tag)
        warnings.warn(
            f"obs.profiling: {len(sigs)} distinct programs compiled for "
            f"{tag!r} — possible silent retrace (ragged chunk sizes or an "
            f"unstable static config)", stacklevel=2)


def compile_count(tag: str | None = None) -> int:
    """Number of fresh compiles recorded (for ``tag``, or in total)."""
    if tag is None:
        return len(_COMPILE_EVENTS)
    return sum(1 for t, _ in _COMPILE_EVENTS if t == tag)


def compile_events(tag: str | None = None) -> list:
    """The recorded ``(tag, signature)`` events, optionally filtered."""
    if tag is None:
        return list(_COMPILE_EVENTS)
    return [(t, s) for t, s in _COMPILE_EVENTS if t == tag]


def reset_compiles() -> None:
    """Clear the compile-event log (test isolation)."""
    _COMPILE_EVENTS.clear()
    _WARNED_TAGS.clear()


@contextlib.contextmanager
def stage(name: str, writer=None, **fields):
    """Wall-clock a host-side stage; emits a ``profile`` record when a
    ``MetricWriter`` is attached.  The yielded dict is live — callers can
    add fields (e.g. ``info["compile_s"] = ...`` for the compile/execute
    split) before the record is written on exit."""
    info = dict(fields)
    t0 = time.perf_counter()
    try:
        yield info
    finally:
        wall = time.perf_counter() - t0
        info["wall_s"] = wall
        if writer is not None:
            writer.write("profile", stage=name, **info)


@contextlib.contextmanager
def profiler_trace(trace_dir=None):
    """Opt-in ``jax.profiler`` trace: active only when ``trace_dir`` is a
    path, a transparent no-op otherwise (so benchmark code can wrap its
    hot section unconditionally)."""
    if not trace_dir:
        yield
        return
    import jax
    with jax.profiler.trace(str(trace_dir)):
        yield
