"""Mamba2 / SSD (state-space duality) block.

TPU adaptation (see DESIGN.md §4): the original CUDA kernel uses warp-level
scans; here the SSD is expressed as a *chunked* scan — intra-chunk terms are
dense (Q×Q) matmuls that map onto the MXU, and the inter-chunk recurrence is a
short ``lax.scan`` over chunk states (L/Q steps).  The hot intra-chunk path has
a Pallas kernel (``repro.kernels.ssd_scan``); this module holds the pure-jnp
reference path used for training and as the oracle.

Layout follows the Mamba2 paper: input projection produces
``[z (d_inner), x (d_inner), B (G·N), C (G·N), dt (H)]``; x/B/C pass through a
short causal depthwise conv; the SSD mixes sequence information; a gated
RMSNorm and output projection close the block.  Decode keeps a constant-size
state: conv tail (width-1 tokens) + SSM state (H, P, N).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .core import linear, linear_init, rmsnorm
from .sharding import batch_spec, constrain


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_model: int
    d_inner: int                 # = expand * d_model (H * headdim)
    head_dim: int = 64           # P
    n_groups: int = 1            # G (B/C groups)
    d_state: int = 128           # N
    conv_width: int = 4
    chunk: int = 128             # Q — SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssm_init(key, cfg: SSMCfg, *, dtype=jnp.float32):
    kin, kout, kconv, kdt = jax.random.split(key, 4)
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    d_in_proj = 2 * cfg.d_inner + 2 * G * N + H
    d_conv = cfg.d_inner + 2 * G * N   # x, B, C share the conv
    # dt bias initialised so softplus(dt_bias) ∈ [dt_min, dt_max] (mamba2 init)
    u = jax.random.uniform(kdt, (H,))
    dt0 = jnp.exp(u * (math.log(cfg.dt_max) - math.log(cfg.dt_min))
                  + math.log(cfg.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))  # inverse softplus
    p = {
        "in_proj": linear_init(kin, cfg.d_model, d_in_proj, dtype=dtype),
        "conv_w": (jax.random.normal(kconv, (cfg.conv_width, d_conv))
                   * (1.0 / math.sqrt(cfg.conv_width))).astype(dtype),
        "conv_b": jnp.zeros((d_conv,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),  # A = -exp(A_log)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "norm": {"scale": jnp.ones((cfg.d_inner,), dtype)},
        "out_proj": linear_init(kout, cfg.d_inner, cfg.d_model, dtype=dtype),
    }
    return p


def ssm_spec(cfg: SSMCfg):
    return {
        "in_proj": {"w": P(None, "model")},
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "D": P(None),
        "dt_bias": P(None),
        "norm": {"scale": P(None)},
        "out_proj": {"w": P("model", None)},
    }


def _split_proj(cfg: SSMCfg, zxbcdt):
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    di = cfg.d_inner
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: di + di + 2 * G * N]
    dt = zxbcdt[..., di + di + 2 * G * N:]
    return z, xBC, dt


def _causal_conv(xBC, w, b, *, tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  xBC: (B,L,Dc), w: (W,Dc).

    ``tail``: (B, W-1, Dc) previous tokens (decode / chunked prefill)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros(xBC.shape[:1] + (W - 1,) + xBC.shape[2:], xBC.dtype)
    xpad = jnp.concatenate([tail, xBC], axis=1)
    # sum_w xpad[:, t+w, :] * w[w] — unrolled small W
    out = sum(xpad[:, i: i + xBC.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + b), xpad[:, -(W - 1):, :]


def ssd_reference(x, dt, A, Bm, Cm, D, *, chunk: int,
                  init_state: Optional[jnp.ndarray] = None,
                  return_state: bool = False):
    """Chunked SSD.  x:(B,L,H,P) dt:(B,L,H) A:(H) Bm/Cm:(B,L,G,N) D:(H).

    Returns y:(B,L,H,P) [and final state (B,H,P,N)].  All math in f32.
    """
    Bsz, L, H, Pd = x.shape
    G = Bm.shape[2]
    N = Bm.shape[3]
    rep = H // G
    Q = min(chunk, L)
    Lorig = L
    if L % Q:
        # pad with dt=0 steps: decay exp(0·A)=1 and zero state contribution,
        # so padded positions are inert; outputs are sliced back below.
        pad = Q - L % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        L = L + pad
    nc = L // Q

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)   # (B,L,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)

    # reshape to chunks
    xc = xf.reshape(Bsz, nc, Q, H, Pd)
    dtc = dtf.reshape(Bsz, nc, Q, H)
    Bc = Bf.reshape(Bsz, nc, Q, H, N)
    Cc = Cf.reshape(Bsz, nc, Q, H, N)

    a = dtc * A[None, None, None, :]          # (B,nc,Q,H) log-decay (negative)
    a_cs = jnp.cumsum(a, axis=2)              # inclusive cumsum within chunk

    # intra-chunk: y[i] += sum_{j<=i} C_i·B_j exp(a_cs[i]-a_cs[j]) dt_j x_j
    seg = a_cs[:, :, :, None, :] - a_cs[:, :, None, :, :]   # (B,nc,Q,Q,H) i,j
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc)           # (B,nc,Q,Q,H)
    M = CB * Lmat * dtc[:, :, None, :, :]                   # weight on x_j
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M, xc)

    # chunk summaries: state contribution of each chunk
    decay_to_end = jnp.exp(a_cs[:, :, -1:, :] - a_cs)       # (B,nc,Q,H)
    Sc = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                    decay_to_end * dtc, Bc, xc)             # (B,nc,H,P,N)
    chunk_decay = jnp.exp(a_cs[:, :, -1, :])                # (B,nc,H)

    # inter-chunk recurrence over chunk states
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, Pd, N), jnp.float32)

    def step(S, inp):
        Sc_c, dec_c = inp                                   # (B,H,P,N), (B,H)
        S_new = S * dec_c[:, :, None, None] + Sc_c
        return S_new, S                                     # emit state *before* chunk

    S_last, S_prev = jax.lax.scan(
        step, init_state,
        (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                     # (B,nc,H,P,N)

    # inter-chunk output: y[i] += C_i exp(a_cs[i]) S_prev
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         Cc * jnp.exp(a_cs)[..., None], S_prev)

    y = (y_intra + y_inter).reshape(Bsz, L, H, Pd)[:, :Lorig]
    y = y + x.astype(jnp.float32)[:, :Lorig] * D[None, None, :, None]
    if return_state:
        return y, S_last
    return y


def ssm_forward(p, cfg: SSMCfg, xin, *, impl: str = "xla",
                compute_dtype=jnp.bfloat16, return_state: bool = False):
    """Full-sequence Mamba2 block.  xin: (B, L, d_model)."""
    Bsz, L, _ = xin.shape
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    zxbcdt = linear(p["in_proj"], xin, compute_dtype=compute_dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_tail = _causal_conv(xBC, p["conv_w"].astype(compute_dtype),
                                  p["conv_b"].astype(compute_dtype))
    x = xBC[..., : cfg.d_inner].reshape(Bsz, L, H, cfg.head_dim)
    Bm = xBC[..., cfg.d_inner: cfg.d_inner + G * N].reshape(Bsz, L, G, N)
    Cm = xBC[..., cfg.d_inner + G * N:].reshape(Bsz, L, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    x = constrain(x, batch_spec(None, "model", None))
    if impl == "pallas":
        from repro.kernels import ops as kops
        y, S = kops.ssd_scan(x, dt, A, Bm, Cm, p["D"], chunk=cfg.chunk)
    else:
        y, S = ssd_reference(x, dt, A, Bm, Cm, p["D"], chunk=cfg.chunk,
                             return_state=True)
    y = y.astype(compute_dtype).reshape(Bsz, L, cfg.d_inner)
    y = constrain(y, batch_spec(None, "model"))
    # gated RMSNorm (norm(y * silu(z)) in mamba2)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y, compute_dtype=compute_dtype)
    if return_state:
        return out, {"conv": conv_tail, "ssm": S}
    return out


def init_ssm_state(B: int, cfg: SSMCfg, dtype=jnp.bfloat16):
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    d_conv = cfg.d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((B, cfg.conv_width - 1, d_conv), dtype),
        "ssm": jnp.zeros((B, H, cfg.head_dim, N), jnp.float32),
    }


def ssm_state_spec(cfg: SSMCfg):
    return {"conv": batch_spec(None, "model"),
            "ssm": batch_spec("model", None, None)}


def ssm_decode(p, cfg: SSMCfg, xin, state, *, compute_dtype=jnp.bfloat16):
    """One-token decode.  xin: (B,1,d_model); state {"conv","ssm"}."""
    Bsz = xin.shape[0]
    H, G, N = cfg.n_heads, cfg.n_groups, cfg.d_state
    zxbcdt = linear(p["in_proj"], xin, compute_dtype=compute_dtype)
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC, conv_tail = _causal_conv(
        xBC, p["conv_w"].astype(compute_dtype),
        p["conv_b"].astype(compute_dtype),
        tail=state["conv"].astype(compute_dtype))
    x = xBC[:, 0, : cfg.d_inner].reshape(Bsz, H, cfg.head_dim)
    Bm = xBC[:, 0, cfg.d_inner: cfg.d_inner + G * N].reshape(Bsz, G, N)
    Cm = xBC[:, 0, cfg.d_inner + G * N:].reshape(Bsz, G, N)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    rep = H // G
    Bf = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)   # (B,H,N)
    Cf = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)

    dA = jnp.exp(dt1 * A[None, :])                          # (B,H)
    S = state["ssm"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt1, Bf, x.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", Cf, S)
    y = y + x.astype(jnp.float32) * p["D"][None, :, None]
    y = y.astype(compute_dtype).reshape(Bsz, 1, cfg.d_inner)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y, compute_dtype=compute_dtype)
    return out, {"conv": conv_tail.astype(state["conv"].dtype), "ssm": S}
