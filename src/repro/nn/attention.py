"""Grouped-query attention with optional QKV-bias, qk-norm, sliding window,
cross-attention, and KV-cache decode.  Tensor-parallel over heads ("model").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from . import core
from .core import linear, linear_init, rmsnorm, rmsnorm_init
from .rotary import apply_rope, rope_cos_sin
from .sharding import batch_spec, constrain

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnCfg:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None      # sliding-window size (tokens), None = full
    cross: bool = False               # cross-attention (kv from encoder states)
    d_kv_in: Optional[int] = None     # input dim for kv projections (cross)
    ring: bool = False                # decode KV cache = ring buffer of size
    # `window` instead of the full sequence (beyond-paper: 64x cache-byte
    # reduction for long_500k sliding-window decode; see §Perf)


def attn_init(key, cfg: AttnCfg, *, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d_kv_in = cfg.d_kv_in or cfg.d_model
    p = {
        "q": linear_init(kq, cfg.d_model, cfg.n_heads * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "k": linear_init(kk, d_kv_in, cfg.n_kv_heads * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "v": linear_init(kv, d_kv_in, cfg.n_kv_heads * cfg.d_head,
                         bias=cfg.qkv_bias, dtype=dtype),
        "o": linear_init(ko, cfg.n_heads * cfg.d_head, cfg.d_model,
                         bias=False, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.d_head, dtype)
        p["k_norm"] = rmsnorm_init(cfg.d_head, dtype)
    return p


def attn_spec(cfg: AttnCfg):
    def lin(bias, wspec):
        s = {"w": wspec}
        if bias:
            s["b"] = P(wspec[1])
        return s
    s = {
        "q": lin(cfg.qkv_bias, P(None, "model")),
        "k": lin(cfg.qkv_bias, P(None, "model")),
        "v": lin(cfg.qkv_bias, P(None, "model")),
        "o": lin(False, P("model", None)),
    }
    if cfg.qk_norm:
        s["q_norm"] = {"scale": P(None)}
        s["k_norm"] = {"scale": P(None)}
    return s


def _split_heads(x, n, d):
    return x.reshape(x.shape[:-1] + (n, d))


def _merge_heads(x):
    return x.reshape(x.shape[:-2] + (x.shape[-2] * x.shape[-1],))


def _gqa_scores(q, k, scale):
    """q:(B,L,H,D) k:(B,S,Hkv,D) -> (B,Hkv,G,L,S) f32."""
    B, L, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, L, Hkv, G, D)
    return jnp.einsum("blkgd,bskd->bkgls", qg, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs, v):
    """probs:(B,Hkv,G,L,S) v:(B,S,Hkv,D) -> (B,L,H,D)."""
    B, Hkv, G, L, S = probs.shape
    out = jnp.einsum("bkgls,bskd->blkgd", probs, v.astype(jnp.float32))
    return out.reshape(B, L, Hkv * G, v.shape[-1])


def causal_window_mask(L: int, S: int, *, causal: bool, window: Optional[int],
                       q_offset: int = 0) -> jnp.ndarray:
    """(L,S) bool mask. q position i corresponds to absolute pos i+q_offset."""
    qpos = jnp.arange(L)[:, None] + q_offset
    kpos = jnp.arange(S)[None, :]
    m = jnp.ones((L, S), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _block_mask(iq, ik, bq, bk, causal, window):
    qpos = iq * bq + jnp.arange(bq)[:, None]
    kpos = ik * bk + jnp.arange(bk)[None, :]
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _chunked_fwd(q, k, v, causal, window, scale, bq, bk):
    """Returns (out (B,L,H,D), lse (B,Hkv,G,L) f32)."""
    B, L, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nq, nk = L // bq, S // bk
    qc = q.reshape(B, nq, bq, Hkv, G, D)
    kc = k.reshape(B, nk, bk, Hkv, D)
    vc = v.reshape(B, nk, bk, Hkv, D)

    def q_block(_, inp):
        iq, qb = inp                                   # qb: (B,bq,Hkv,G,D)

        def k_block(carry, kinp):
            m_prev, l_prev, acc = carry
            ik, kb, vb = kinp
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(iq, ik, bq, bk, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p_ = jnp.exp(s - m_new[..., None])
            p_ = jnp.where(m_new[..., None] > NEG_INF / 2, p_, 0.0)
            corr = jnp.where(m_prev > NEG_INF / 2,
                             jnp.exp(m_prev - m_new), 0.0)
            l_new = corr * l_prev + jnp.sum(p_, axis=-1)
            acc = corr[..., None] * acc + jnp.einsum(
                "bkgqs,bskd->bkgqd", p_, vb.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF)
        l0 = jnp.zeros((B, Hkv, G, bq))
        a0 = jnp.zeros((B, Hkv, G, bq, D))
        (m, l, acc), _ = jax.lax.scan(
            k_block, (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kc, 1, 0),
             jnp.moveaxis(vc, 1, 0)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,Hkv,G,bq,D)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))       # (B,Hkv,G,bq)
        return None, (out, lse)

    _, (blocks, lses) = jax.lax.scan(
        q_block, None, (jnp.arange(nq), jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(blocks, 0, 1)                   # (B,nq,Hkv,G,bq,D)
    out = jnp.moveaxis(out, -2, 2)                     # (B,nq,bq,Hkv,G,D)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Hkv, G, L)
    return out.reshape(B, L, H, D).astype(q.dtype), lse


def _chunked_bwd_impl(q, k, v, out, lse, do, causal, window, scale, bq, bk):
    """Flash-style recompute backward: O(bq·bk) working set, accumulating
    dk/dv in an (nk, ...) carry; probs are recomputed from q, k and lse."""
    B, L, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    nq, nk = L // bq, S // bk
    qc = jnp.moveaxis(q.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    oc = jnp.moveaxis(out.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    doc = jnp.moveaxis(do.reshape(B, nq, bq, Hkv, G, D), 1, 0)
    lsec = jnp.moveaxis(lse.reshape(B, Hkv, G, nq, bq), 3, 0)
    kc = k.reshape(B, nk, bk, Hkv, D).astype(jnp.float32)
    vc = v.reshape(B, nk, bk, Hkv, D).astype(jnp.float32)

    def q_block(carry, inp):
        dk_acc, dv_acc = carry
        iq, qb, ob, dob, lseb = inp
        qbf = qb.astype(jnp.float32)
        dobf = dob.astype(jnp.float32)
        # delta = rowsum(do * out): (B,bq,Hkv,G)
        delta = jnp.sum(dobf * ob.astype(jnp.float32), axis=-1)
        delta = jnp.moveaxis(delta, 1, -1)             # (B,Hkv,G,bq)

        def k_block(inner, ik):
            dq_b, dk_acc, dv_acc = inner
            kb, vb = kc[:, ik], vc[:, ik]
            s = jnp.einsum("bqkgd,bskd->bkgqs", qbf, kb,
                           preferred_element_type=jnp.float32) * scale
            mask = _block_mask(iq, ik, bq, bk, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p_ = jnp.exp(s - lseb[..., None])          # (B,Hkv,G,bq,bk)
            p_ = jnp.where(mask[None, None, None], p_, 0.0)
            dob_r = jnp.moveaxis(dobf, 1, 3)           # (B,Hkv,G,bq,D)
            dv_blk = jnp.einsum("bkgqs,bkgqd->bskd", p_, dob_r)
            dp = jnp.einsum("bkgqd,bskd->bkgqs", dob_r, vb)
            ds = p_ * (dp - delta[..., None]) * scale
            dq_b = dq_b + jnp.einsum("bkgqs,bskd->bkgqd", ds, kb)
            dk_blk = jnp.einsum("bkgqs,bkgqd->bskd", ds,
                                jnp.moveaxis(qbf, 1, 3))
            return (dq_b, dk_acc.at[:, ik].add(dk_blk),
                    dv_acc.at[:, ik].add(dv_blk)), None

        dq0 = jnp.zeros((B, Hkv, G, bq, D), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            k_block, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((B, nk, bk, Hkv, D), jnp.float32)
    dv0 = jnp.zeros((B, nk, bk, Hkv, D), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(
        q_block, (dk0, dv0), (jnp.arange(nq), qc, oc, doc, lsec))
    dq = jnp.moveaxis(dqs, 0, 1)                       # (B,nq,Hkv,G,bq,D)
    dq = jnp.moveaxis(dq, -2, 2).reshape(B, L, H, D)
    return (dq.astype(q.dtype), dk.reshape(B, S, Hkv, D).astype(k.dtype),
            dv.reshape(B, S, Hkv, D).astype(v.dtype))


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _chunked_attention_vjp(q, k, v, causal, window, scale, bq, bk):
    return _chunked_fwd(q, k, v, causal, window, scale, bq, bk)[0]


def _cvjp_fwd(q, k, v, causal, window, scale, bq, bk):
    out, lse = _chunked_fwd(q, k, v, causal, window, scale, bq, bk)
    return out, (q, k, v, out, lse)


def _cvjp_bwd(causal, window, scale, bq, bk, res, do):
    q, k, v, out, lse = res
    return _chunked_bwd_impl(q, k, v, out, lse, do, causal, window, scale,
                             bq, bk)


_chunked_attention_vjp.defvjp(_cvjp_fwd, _cvjp_bwd)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int],
                      scale: float, bq: int = 1024, bk: int = 1024):
    """Flash-style double-chunked attention in pure XLA: lax.scan over
    q-blocks (outer) and k-blocks (inner) with an online-softmax carry and a
    RECOMPUTING custom VJP (naive AD through the online-softmax scan stores
    per-step carries and regresses training memory — measured in
    EXPERIMENTS.md §Perf B2).  Working set is O(bq·bk) instead of O(L·S) in
    both directions — the beyond-paper memory optimisation for 32k-token
    prefill/train, and the jnp twin of the Pallas flash kernel.

    q: (B, L, H, D); k/v: (B, S, Hkv, D).  L % bq == 0, S % bk == 0
    (callers pad; see kernels/ops.py for the padding contract)."""
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    return _chunked_attention_vjp(q, k, v, causal, window, scale, bq, bk)


def attn_forward(p, cfg: AttnCfg, x, *, kv_src=None, positions=None,
                 impl: str = "xla", compute_dtype=jnp.bfloat16,
                 return_kv: bool = False):
    """Full-sequence attention (train / prefill).

    x: (B, L, D).  kv_src: (B, S, Dkv) for cross-attention (defaults to x).
    positions: (L,) absolute positions for RoPE (defaults arange).
    """
    B, L, _ = x.shape
    kv_in = x if kv_src is None else kv_src
    S = kv_in.shape[1]
    q = _split_heads(linear(p["q"], x, compute_dtype=compute_dtype),
                     cfg.n_heads, cfg.d_head)
    k = _split_heads(linear(p["k"], kv_in, compute_dtype=compute_dtype),
                     cfg.n_kv_heads, cfg.d_head)
    v = _split_heads(linear(p["v"], kv_in, compute_dtype=compute_dtype),
                     cfg.n_kv_heads, cfg.d_head)
    q = constrain(q, batch_spec(None, "model", None))
    k = constrain(k, batch_spec(None, "model", None))
    v = constrain(v, batch_spec(None, "model", None))
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.rope and not cfg.cross:
        if positions is None:
            positions = jnp.arange(L)
        cos, sin = rope_cos_sin(positions, cfg.d_head, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    scale = 1.0 / math.sqrt(cfg.d_head)
    if impl == "flash" and not cfg.cross and cfg.causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=cfg.window)
    elif impl == "chunked" and not cfg.cross:
        out = chunked_attention(q, k, v, causal=cfg.causal,
                                window=cfg.window, scale=scale)
    else:
        scores = _gqa_scores(q, k, scale)
        if cfg.cross:
            mask = None
        else:
            mask = causal_window_mask(L, S, causal=cfg.causal, window=cfg.window)
        if mask is not None:
            scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v).astype(compute_dtype)
    out = constrain(out, batch_spec(None, "model", None))
    y = linear(p["o"], _merge_heads(out), compute_dtype=compute_dtype)
    if return_kv:
        return y, (k, v)
    return y


def init_kv_cache(B: int, S: int, cfg: AttnCfg, dtype=jnp.bfloat16):
    if cfg.ring and cfg.window is not None:
        S = min(S, cfg.window)
    shape = (B, S, cfg.n_kv_heads, cfg.d_head)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def kv_cache_spec(cfg: AttnCfg):
    # batch over data axes, kv heads over model.
    return {"k": batch_spec(None, "model", None), "v": batch_spec(None, "model", None)}


def attn_decode(p, cfg: AttnCfg, x, cache, pos, *,
                compute_dtype=jnp.bfloat16):
    """One-token decode.  x: (B, 1, D); cache: {"k","v"}: (B, S, Hkv, Dh);
    pos: scalar int32 — the absolute position of the new token.  Returns
    (y, new_cache).  For cross-attention the cache holds the (static)
    encoder k/v and is not updated (pos ignored for masking length)."""
    B = x.shape[0]
    q = _split_heads(linear(p["q"], x, compute_dtype=compute_dtype),
                     cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
    scale = 1.0 / math.sqrt(cfg.d_head)

    if cfg.cross:
        k, v = cache["k"], cache["v"]
        scores = _gqa_scores(q, k, scale)
        probs = jax.nn.softmax(scores, axis=-1)
        out = _gqa_out(probs, v).astype(compute_dtype)
        y = linear(p["o"], _merge_heads(out), compute_dtype=compute_dtype)
        return y, cache

    k_new = _split_heads(linear(p["k"], x, compute_dtype=compute_dtype),
                         cfg.n_kv_heads, cfg.d_head)
    v_new = _split_heads(linear(p["v"], x, compute_dtype=compute_dtype),
                         cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k_new = rmsnorm(p["k_norm"], k_new)
    if cfg.rope:
        cos, sin = rope_cos_sin(pos[None] if jnp.ndim(pos) == 0 else pos,
                                cfg.d_head, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)

    S = cache["k"].shape[1]
    ring = cfg.ring and cfg.window is not None
    write_at = (pos % S) if ring else pos
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), write_at, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), write_at, axis=1)
    k = constrain(k, batch_spec(None, "model", None))
    v = constrain(v, batch_spec(None, "model", None))

    scores = _gqa_scores(q, k, scale)  # (B,Hkv,G,1,S)
    kpos = jnp.arange(S)
    if ring:
        # slot s holds global position pos - ((pos - s) mod S); every live
        # slot is within the window by construction — only mask slots not
        # yet written (global position < 0 during warm-up).
        gpos = pos - jnp.mod(pos - kpos, S)
        valid = gpos >= 0
    else:
        valid = kpos <= pos
        if cfg.window is not None:
            valid &= kpos > pos - cfg.window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v).astype(compute_dtype)
    y = linear(p["o"], _merge_heads(out), compute_dtype=compute_dtype)
    return y, {"k": k, "v": v}
