from . import attention, core, mla, mlp, moe, rotary, sharding, ssm  # noqa: F401
