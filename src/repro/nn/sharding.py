"""Mesh context + activation sharding-constraint helpers.

We thread the mesh through an explicit context (not jax's implicit resource
env) so that model code can emit ``with_sharding_constraint`` only when a mesh
is active, and single-device tests/smoke runs stay constraint-free.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        yield mesh
    finally:
        _state.mesh = prev


def batch_axes() -> tuple:
    """Mesh axes over which the batch dim is sharded ('pod' first if present)."""
    mesh = current_mesh()
    if mesh is None:
        return ()
    names = mesh.axis_names
    axes = tuple(a for a in ("pod", "data") if a in names)
    return axes


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (JAX requires
    divisibility).  For tuple entries the longest dividing prefix is kept.
    Dims beyond ``len(spec)`` are left unsharded (PartitionSpec semantics)."""
    new = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            new.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep, prod = [], 1
        for a in axes:
            sz = mesh.shape[a]
            if shape[i] % (prod * sz) == 0:
                keep.append(a)
                prod *= sz
            else:
                break
        new.append(tuple(keep) if len(keep) > 1
                   else (keep[0] if keep else None))
    return P(*new)


def constrain(x, spec: P):
    """Apply a (shape-fitted) sharding constraint iff a mesh is active."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = fit_spec(spec, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_spec(*rest) -> P:
    """PartitionSpec with leading batch dim over ('pod','data')."""
    ba = batch_axes()
    lead = ba if len(ba) != 1 else ba[0]
    return P(lead if ba else None, *rest)


def shard_batch_act(x, *rest):
    """Constrain activation whose dim0 is batch; rest are explicit axes."""
    return constrain(x, batch_spec(*rest))


def named_sharding(spec: P) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec)


def make_param_shardings(specs) -> object:
    """Map a PartitionSpec pytree to NamedSharding pytree (or None w/o mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda s: isinstance(s, P),
    )
