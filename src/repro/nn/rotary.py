"""Rotary position embeddings (RoPE)."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(d_head: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def rope_cos_sin(positions: jnp.ndarray, d_head: int, theta: float = 10000.0):
    """positions: (..., L) int -> cos/sin (..., L, d_head//2) f32."""
    freqs = rope_freqs(d_head, theta)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., L, H, D). cos/sin: (..., L, D//2) broadcast over heads."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dtype)
