"""Core building blocks: initializers, dtype policy, param/spec pytree helpers.

Params are plain nested dicts of jnp arrays.  Every layer module exposes
``<layer>_init(key, ...) -> params``, ``<layer>_spec(...) -> PartitionSpec
pytree`` (mirroring the params tree), and an apply function.  Sharding specs
use the logical mesh axes ``("data", "model")`` (plus ``"pod"`` on multi-pod
meshes; batch dims are sharded over ``("pod","data")`` via the helper in
``repro.launch.mesh``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict pytree of arrays
Specs = Any   # nested dict pytree of PartitionSpec


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Mixed-precision policy: params vs compute vs reductions."""
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # softmax / norms / router logits always accumulate in float32.

    def cast_compute(self, x):
        return x.astype(self.compute_dtype)


DEFAULT_POLICY = DTypePolicy()
BF16_POLICY = DTypePolicy(param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def truncated_normal_init(key, shape, scale, dtype):
    stddev = scale / max(1.0, math.sqrt(shape[0] if len(shape) >= 1 else 1))
    # fan-in scaled normal (matches common transformer init)
    fan_in = shape[0] if len(shape) >= 2 else 1
    stddev = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * stddev).astype(dtype)


def normal_init(key, shape, stddev, dtype):
    return (jax.random.normal(key, shape) * stddev).astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Linear
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: float = 1.0) -> Params:
    p = {"w": truncated_normal_init(key, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = zeros_init((d_out,), dtype)
    return p


def linear_spec(*, bias: bool = False, w_spec=P(None, None),
                b_spec=None) -> Specs:
    s = {"w": w_spec}
    if bias:
        s["b"] = b_spec if b_spec is not None else P(w_spec[1]) if len(w_spec) == 2 else P(None)
    return s


def linear(p: Params, x: jnp.ndarray, *, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    w = p["w"].astype(compute_dtype)
    y = jnp.einsum("...i,io->...o", x.astype(compute_dtype), w)
    if "b" in p:
        y = y + p["b"].astype(compute_dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": ones_init((d,), dtype)}


def rmsnorm_spec() -> Specs:
    return {"scale": P(None)}


def rmsnorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, *, elementwise: bool = True, dtype=jnp.float32) -> Params:
    if not elementwise:  # OLMo non-parametric LN
        return {}
    return {"scale": ones_init((d,), dtype), "bias": zeros_init((d,), dtype)}


def layernorm_spec(*, elementwise: bool = True) -> Specs:
    if not elementwise:
        return {}
    return {"scale": P(None), "bias": P(None)}


def layernorm(p: Params, x: jnp.ndarray, *, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if "scale" in p:
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d: int, *, dtype=jnp.float32) -> Params:
    return {"table": normal_init(key, (vocab, d), 1.0 / math.sqrt(d), dtype)}


def embedding_spec() -> Specs:
    return {"table": P("model", None)}


def embed(p: Params, ids: jnp.ndarray, *, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    return jnp.take(p["table"].astype(compute_dtype), ids, axis=0)


def unembed(p: Params, x: jnp.ndarray, *, compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Tied-embedding logits projection (logits in f32)."""
    table = p["table"].astype(compute_dtype)
    return jnp.einsum("...d,vd->...v", x.astype(compute_dtype), table,
                      preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Activations / misc
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def count_params(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def tree_cast(params: Params, dtype) -> Params:
    return jax.tree.map(lambda p: p.astype(dtype), params)
