"""Multi-head Latent Attention (DeepSeek-V2/V3): compressed-KV attention.

Prefill uses the expanded (standard) formulation; decode uses the *absorbed*
formulation (W_UK folded into the query, W_UV folded into the output) so the
per-token KV-cache is just ``c_kv`` (kv_lora_rank) + ``k_rope`` — the paper's
edge-memory constraint is directly served by this: cache bytes drop from
2·H·Dh to (kv_lora + d_rope) per token (~9x for V3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .core import linear, linear_init, rmsnorm, rmsnorm_init
from .rotary import apply_rope, rope_cos_sin
from .attention import NEG_INF, causal_window_mask
from .sharding import batch_spec, constrain


@dataclasses.dataclass(frozen=True)
class MLACfg:
    d_model: int
    n_heads: int
    q_lora_rank: int = 0          # 0 -> direct q projection
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    causal: bool = True
    window: Optional[int] = None


def mla_init(key, cfg: MLACfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    p = {}
    if cfg.q_lora_rank:
        p["q_down"] = linear_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype=dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, dtype)
        p["q_up"] = linear_init(ks[1], cfg.q_lora_rank, H * qd, dtype=dtype)
    else:
        p["q_proj"] = linear_init(ks[1], cfg.d_model, H * qd, dtype=dtype)
    p["kv_down"] = linear_init(ks[2], cfg.d_model,
                               cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=dtype)
    p["kv_norm"] = rmsnorm_init(cfg.kv_lora_rank, dtype)
    p["kv_up"] = linear_init(ks[3], cfg.kv_lora_rank,
                             H * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dtype)
    p["o"] = linear_init(ks[4], H * cfg.v_head_dim, cfg.d_model, dtype=dtype)
    return p


def mla_spec(cfg: MLACfg):
    s = {
        "kv_down": {"w": P(None, None)},
        "kv_norm": {"scale": P(None)},
        "kv_up": {"w": P(None, "model")},
        "o": {"w": P("model", None)},
    }
    if cfg.q_lora_rank:
        s["q_down"] = {"w": P(None, None)}
        s["q_norm"] = {"scale": P(None)}
        s["q_up"] = {"w": P(None, "model")}
    else:
        s["q_proj"] = {"w": P(None, "model")}
    return s


def _project_q(p, cfg: MLACfg, x, compute_dtype):
    H = cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    if cfg.q_lora_rank:
        qc = rmsnorm(p["q_norm"], linear(p["q_down"], x, compute_dtype=compute_dtype))
        q = linear(p["q_up"], qc, compute_dtype=compute_dtype)
    else:
        q = linear(p["q_proj"], x, compute_dtype=compute_dtype)
    q = q.reshape(x.shape[:-1] + (H, qd))
    return q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]


def _compress_kv(p, cfg: MLACfg, x, positions, compute_dtype):
    """Returns (c_kv normalized (B,S,C), k_rope roped (B,S,1,dr))."""
    ckr = linear(p["kv_down"], x, compute_dtype=compute_dtype)
    c_kv = rmsnorm(p["kv_norm"], ckr[..., : cfg.kv_lora_rank])
    k_rope = ckr[..., cfg.kv_lora_rank:][..., None, :]  # single shared rope head
    cos, sin = rope_cos_sin(positions, cfg.qk_rope_dim, cfg.rope_theta)
    k_rope = apply_rope(k_rope, cos, sin)
    return c_kv, k_rope


def mla_forward(p, cfg: MLACfg, x, *, positions=None,
                compute_dtype=jnp.bfloat16, return_kv: bool = False):
    """Full-sequence MLA (train / prefill), expanded formulation."""
    B, L, _ = x.shape
    H = cfg.n_heads
    if positions is None:
        positions = jnp.arange(L)
    q_nope, q_rope = _project_q(p, cfg, x, compute_dtype)
    cos, sin = rope_cos_sin(positions, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_kv, k_rope = _compress_kv(p, cfg, x, positions, compute_dtype)
    kv = linear(p["kv_up"], c_kv, compute_dtype=compute_dtype)
    kv = kv.reshape(B, L, H, cfg.qk_nope_dim + cfg.v_head_dim)
    k_nope, v = kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim:]
    k_nope = constrain(k_nope, batch_spec(None, "model", None))
    v = constrain(v, batch_spec(None, "model", None))

    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (jnp.einsum("blhd,bshd->bhls", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("blhd,bsxd->bhls", q_rope,
                           jnp.broadcast_to(k_rope, (B, L, 1, cfg.qk_rope_dim)),
                           preferred_element_type=jnp.float32)) * scale
    mask = causal_window_mask(L, L, causal=cfg.causal, window=cfg.window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhls,bshd->blhd", probs, v.astype(jnp.float32))
    out = out.astype(compute_dtype).reshape(B, L, H * cfg.v_head_dim)
    y = linear(p["o"], out, compute_dtype=compute_dtype)
    if return_kv:
        return y, (c_kv, k_rope[:, :, 0, :])
    return y


def init_mla_cache(B: int, S: int, cfg: MLACfg, dtype=jnp.bfloat16):
    return {
        "c_kv": jnp.zeros((B, S, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((B, S, cfg.qk_rope_dim), dtype),
    }


def mla_cache_spec(cfg: MLACfg):
    # no head dim -> shard sequence over "model" so huge contexts fit.
    return {"c_kv": batch_spec("model", None), "k_rope": batch_spec("model", None)}


def mla_decode(p, cfg: MLACfg, x, cache, pos, *, compute_dtype=jnp.bfloat16):
    """One-token absorbed-MLA decode.  x: (B,1,D); cache c_kv:(B,S,C)."""
    B = x.shape[0]
    H = cfg.n_heads
    C = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(p, cfg, x, compute_dtype)  # (B,1,H,*)
    posv = pos[None] if jnp.ndim(pos) == 0 else pos
    cos, sin = rope_cos_sin(posv, cfg.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    c_new, kr_new = _compress_kv(p, cfg, x, posv, compute_dtype)

    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new[:, :, 0, :].astype(cache["k_rope"].dtype), pos, axis=1)

    W = p["kv_up"]["w"].astype(compute_dtype)  # (C, H*(nope+v))
    W = W.reshape(C, H, cfg.qk_nope_dim + cfg.v_head_dim)
    W_uk, W_uv = W[..., : cfg.qk_nope_dim], W[..., cfg.qk_nope_dim:]
    # absorb: q_lat (B,1,H,C)
    q_lat = jnp.einsum("blhd,chd->blhc", q_nope, W_uk)
    scale = 1.0 / math.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (jnp.einsum("blhc,bsc->bhls", q_lat, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("blhd,bsd->bhls", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    S = c_kv.shape[1]
    kpos = jnp.arange(S)
    valid = kpos <= pos
    if cfg.window is not None:
        valid &= kpos > pos - cfg.window
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhls,bsc->blhc", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("blhc,chv->blhv", ctx.astype(compute_dtype), W_uv)
    y = linear(p["o"], out.reshape(B, 1, H * cfg.v_head_dim),
               compute_dtype=compute_dtype)
    return y, {"c_kv": c_kv, "k_rope": k_rope}
