"""Feed-forward blocks: SwiGLU (llama/qwen family) and GELU (whisper/olmo opt)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .core import gelu, linear, linear_init, silu
from .sharding import batch_spec, constrain


@dataclasses.dataclass(frozen=True)
class MLPCfg:
    d_model: int
    d_ff: int
    gated: bool = True            # SwiGLU if True, GELU otherwise
    act: str = "silu"


def mlp_init(key, cfg: MLPCfg, *, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": linear_init(k1, cfg.d_model, cfg.d_ff, dtype=dtype),
        "down": linear_init(k2, cfg.d_ff, cfg.d_model, dtype=dtype),
    }
    if cfg.gated:
        p["gate"] = linear_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype)
    return p


def mlp_spec(cfg: MLPCfg):
    s = {"up": {"w": P(None, "model")}, "down": {"w": P("model", None)}}
    if cfg.gated:
        s["gate"] = {"w": P(None, "model")}
    return s


def mlp_apply(p, cfg: MLPCfg, x, *, compute_dtype=jnp.bfloat16):
    act = silu if cfg.act == "silu" else gelu
    h = linear(p["up"], x, compute_dtype=compute_dtype)
    if cfg.gated:
        h = act(linear(p["gate"], x, compute_dtype=compute_dtype)) * h
    else:
        h = act(h)
    h = constrain(h, batch_spec(None, "model"))
    return linear(p["down"], h, compute_dtype=compute_dtype)
