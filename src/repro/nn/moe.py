"""Mixture-of-Experts with shared experts + top-k routed experts
(DeepSeek-V2/V3 style), sort-based capacity dispatch.

Why sort-based: the classic one-hot dispatch tensor (T, E, C) is infeasible at
E=256 / T~1M.  We instead sort the (token, expert) assignments by expert id,
rank tokens within an expert, drop overflow beyond the capacity, and scatter
into a dense (E, C, d) buffer that is expert-parallel over the "model" mesh
axis — GSPMD lowers the scatter/gather to all-to-all style collectives.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .core import linear_init, silu
from .mlp import MLPCfg, mlp_apply, mlp_init, mlp_spec
from .sharding import constrain


@dataclasses.dataclass(frozen=True)
class MoECfg:
    d_model: int
    d_ff: int                      # per routed expert
    n_experts: int                 # routed experts
    top_k: int
    n_shared: int = 0              # shared experts (each of size d_ff)
    capacity_factor: float = 1.25
    aux_coef: float = 0.001
    router_dtype: object = jnp.float32
    dispatch: str = "gspmd"        # "gspmd" (global scatter; simple, but
    # GSPMD lowers it to full-buffer all-reduces) | "shardmap" (local
    # dispatch per data shard + model-axis psum combine — the TPU-native
    # expert-parallel path, §Perf iteration A2)


def moe_init(key, cfg: MoECfg, *, dtype=jnp.float32):
    kr, ku, kg, kd, ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": (jax.random.normal(kr, (d, E)) * scale).astype(jnp.float32)},
        "up": (jax.random.normal(ku, (E, d, f)) * scale).astype(dtype),
        "gate": (jax.random.normal(kg, (E, d, f)) * scale).astype(dtype),
        "down": (jax.random.normal(kd, (E, f, d)) * (1.0 / math.sqrt(f))).astype(dtype),
    }
    if cfg.n_shared:
        p["shared"] = mlp_init(
            ks, MLPCfg(cfg.d_model, cfg.d_ff * cfg.n_shared), dtype=dtype)
    return p


def moe_spec(cfg: MoECfg):
    s = {
        "router": {"w": P(None, None)},
        "up": P("model", None, None),
        "gate": P("model", None, None),
        "down": P("model", None, None),
    }
    if cfg.n_shared:
        s["shared"] = mlp_spec(MLPCfg(cfg.d_model, cfg.d_ff * cfg.n_shared))
    return s


def _capacity(T: int, cfg: MoECfg) -> int:
    cap = int(math.ceil(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(cap, cfg.top_k)


def _local_dispatch_combine(router_w, up, gate, down, xl, cfg: MoECfg,
                            compute_dtype, model_axis: str,
                            all_axes: tuple):
    """shard_map body: tokens are THIS data-shard's slice; up/gate/down are
    THIS model-shard's expert slice (E_loc, ...).  No cross-device traffic
    except the final psum over the model axis."""
    E = cfg.n_experts
    E_loc = up.shape[0]
    K = cfg.top_k
    B_loc, L, D = xl.shape
    T = B_loc * L
    cap = max(int(math.ceil(T * K * cfg.capacity_factor / E)), K)
    xt = xl.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, K)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)

    flat_ids = ids.reshape(T * K)
    flat_w = w.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_ids, stable=True)
    e_sorted = flat_ids[order]
    t_sorted = flat_tok[order]
    w_sorted = flat_w[order]
    starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(T * K) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, E * cap)

    # dispatch LOCALLY into the full (E·cap) buffer, then slice my experts
    tok_vals = jnp.where(keep[:, None], xt[t_sorted].astype(compute_dtype), 0)
    buf = jnp.zeros((E * cap + 1, D), compute_dtype).at[slot].add(tok_vals)
    midx = jax.lax.axis_index(model_axis)
    mine = jax.lax.dynamic_slice_in_dim(buf[: E * cap].reshape(E, cap, D),
                                        midx * E_loc, E_loc, axis=0)

    up_h = jnp.einsum("ecd,edf->ecf", mine, up.astype(compute_dtype))
    gate_h = jnp.einsum("ecd,edf->ecf", mine, gate.astype(compute_dtype))
    out = jnp.einsum("ecf,efd->ecd", silu(gate_h) * up_h,
                     down.astype(compute_dtype))

    # combine MY experts' contributions, then sum over the model axis
    out_flat = jnp.concatenate(
        [out.reshape(E_loc * cap, D), jnp.zeros((1, D), compute_dtype)], 0)
    myslot = slot - midx * E_loc * cap
    valid = keep & (myslot >= 0) & (myslot < E_loc * cap)
    contrib = out_flat[jnp.where(valid, myslot, E_loc * cap)] \
        * jnp.where(valid, w_sorted, 0.0)[:, None].astype(compute_dtype)
    y = jnp.zeros((T, D), compute_dtype).at[t_sorted].add(contrib)
    y = jax.lax.psum(y, model_axis)

    frac = jnp.zeros(E, jnp.float32).at[flat_ids].add(1.0) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.aux_coef * E * jnp.sum(frac * mean_prob)
    aux = jax.lax.pmean(aux, all_axes)          # invariant across shards
    return y.reshape(B_loc, L, D), aux


def moe_apply_shardmap(p, cfg: MoECfg, x, *, compute_dtype=jnp.bfloat16):
    """Expert-parallel MoE via shard_map (requires an active mesh whose
    'model' size divides n_experts).  Collective cost per layer: one bf16
    psum of the (T_local, D) activations over the model axis — vs the
    GSPMD path's full (E·cap, D) buffer all-reduces."""
    import functools
    import inspect
    from jax.sharding import PartitionSpec as P
    from .sharding import batch_axes, current_mesh
    try:
        shard_map = jax.shard_map
    except AttributeError:        # pre-0.6 jax: experimental namespace
        from jax.experimental.shard_map import shard_map
    # the replication-check kwarg was renamed check_rep -> check_vma
    check_kw = ("check_vma" if "check_vma"
                in inspect.signature(shard_map).parameters else "check_rep")
    mesh = current_mesh()
    assert mesh is not None and "model" in mesh.axis_names
    ba = batch_axes()
    lead = ba if len(ba) != 1 else ba[0]
    all_axes = tuple(mesh.axis_names)
    body = functools.partial(
        _local_dispatch_combine, cfg=cfg, compute_dtype=compute_dtype,
        model_axis="model", all_axes=all_axes)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None),
                  P(lead if ba else None, None, None)),
        out_specs=(P(lead if ba else None, None, None), P()),
        **{check_kw: False},
    )(p["router"]["w"], p["up"], p["gate"], p["down"], x)
    if "shared" in p:
        y = y + mlp_apply(p["shared"],
                          MLPCfg(cfg.d_model, cfg.d_ff * cfg.n_shared), x,
                          compute_dtype=compute_dtype)
    return y, aux


def moe_apply(p, cfg: MoECfg, x, *, compute_dtype=jnp.bfloat16):
    """x: (B, L, D) -> (y, aux_loss)."""
    if cfg.dispatch == "shardmap":
        from .sharding import current_mesh
        if current_mesh() is not None:
            return moe_apply_shardmap(p, cfg, x,
                                      compute_dtype=compute_dtype)
        # no mesh (smoke tests / single host): fall through to gspmd
    B, L, D = x.shape
    T = B * L
    E, K = cfg.n_experts, cfg.top_k
    cap = _capacity(T, cfg)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, K)                     # (T,K)
    w = w / (jnp.sum(w, axis=-1, keepdims=True) + 1e-9)  # renormalize top-k

    # --- flatten assignments and sort by expert id --------------------------
    flat_ids = ids.reshape(T * K)
    flat_w = w.reshape(T * K)
    flat_tok = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_ids, stable=True)
    e_sorted = flat_ids[order]
    t_sorted = flat_tok[order]
    w_sorted = flat_w[order]

    # rank of each assignment within its expert
    starts = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    rank = jnp.arange(T * K) - starts[e_sorted]
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, E * cap)  # sentinel row

    # --- dispatch ------------------------------------------------------------
    tok_vals = jnp.where(keep[:, None], xt[t_sorted].astype(compute_dtype), 0)
    buf = jnp.zeros((E * cap + 1, D), compute_dtype).at[slot].add(tok_vals)
    h = buf[: E * cap].reshape(E, cap, D)
    h = constrain(h, P("model", None, None))

    # --- expert FFN (SwiGLU) --------------------------------------------------
    up = jnp.einsum("ecd,edf->ecf", h, p["up"].astype(compute_dtype))
    gate = jnp.einsum("ecd,edf->ecf", h, p["gate"].astype(compute_dtype))
    out = jnp.einsum("ecf,efd->ecd", silu(gate) * up,
                     p["down"].astype(compute_dtype))
    out = constrain(out, P("model", None, None))

    # --- combine --------------------------------------------------------------
    out_flat = jnp.concatenate(
        [out.reshape(E * cap, D), jnp.zeros((1, D), compute_dtype)], axis=0)
    contrib = out_flat[slot] * w_sorted[:, None].astype(compute_dtype)
    y = jnp.zeros((T, D), compute_dtype).at[t_sorted].add(contrib)
    y = y.reshape(B, L, D)

    # --- shared experts -------------------------------------------------------
    if "shared" in p:
        y = y + mlp_apply(p["shared"],
                          MLPCfg(cfg.d_model, cfg.d_ff * cfg.n_shared), x,
                          compute_dtype=compute_dtype)

    # --- load-balance aux loss (Switch-style) ---------------------------------
    frac = jnp.zeros(E, jnp.float32).at[flat_ids].add(1.0) / (T * K)
    mean_prob = jnp.mean(probs, axis=0)
    aux = cfg.aux_coef * E * jnp.sum(frac * mean_prob)
    return y, aux
