"""§Roofline — aggregate the dry-run JSON records into the per-(arch ×
shape × mesh) roofline table (compute/memory/collective terms, bottleneck,
MODEL_FLOPS ratio)."""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(dryrun_dir: str = "experiments/dryrun"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def table(recs, mesh: str = "pod16x16"):
    rows = []
    for r in recs:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        ro = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": ro["compute_s"], "memory_s": ro["memory_s"],
            "collective_s": ro["collective_s"],
            "bottleneck": ro["bottleneck"],
            "useful_ratio": ro.get("useful_ratio"),
            "arg_gib": r["memory"].get("argument_size_in_bytes", 0) / 2**30,
            "tmp_gib": r["memory"].get("temp_size_in_bytes", 0) / 2**30,
        })
    return rows


def run(dryrun_dir: str = "experiments/dryrun", verbose=True):
    recs = load_records(dryrun_dir)
    if not recs:
        print("no dry-run records found — run `python -m "
              "repro.launch.dryrun --all` first")
        return []
    out = {}
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table(recs, mesh)
        out[mesh] = rows
        if verbose and rows:
            print(f"\n== {mesh} ({len(rows)} pairs) ==")
            for r in rows:
                ur = f"{r['useful_ratio']:.3f}" if r["useful_ratio"] else "-"
                print(f"{r['arch']:18s} {r['shape']:12s} "
                      f"comp {r['compute_s']:9.4f} mem {r['memory_s']:9.4f} "
                      f"coll {r['collective_s']:9.4f} -> "
                      f"{r['bottleneck']:10s} useful={ur}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    run(args.dir)


if __name__ == "__main__":
    main()
