"""Fig. 6 — convergence performance.

6a: T2DRL episodic reward for different denoising-step counts L.
6b: T2DRL vs DDPG-based T2DRL reward curves.

``--num-envs B`` trains B parallel cells (multi-seed) through the
vectorized core in one compiled run per method; curves then carry a
trailing (B,) seed axis and the summary statistics add a cross-seed
standard deviation.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import EnvCfg
from .common import (history_to_list, reward_summary, save_json,
                     train_and_eval)


def run(episodes: int = 150, Ls=(1, 5, 10), seed: int = 0,
        num_envs: int = 1, verbose=True):
    env = EnvCfg(U=10, M=10, T=10, K=10)
    out = {"episodes": episodes, "num_envs": num_envs, "curves": {}}

    # Fig 6a: denoising-step sweep
    for L in Ls:
        hist, ev = train_and_eval("t2drl", env=env, episodes=episodes, L=L,
                                  seed=seed, num_envs=num_envs)
        r = np.asarray(hist["episode_reward"])
        out["curves"][f"t2drl_L{L}"] = history_to_list(hist)
        out[f"t2drl_L{L}"] = {**reward_summary(r), **ev}
        if verbose:
            print(f"T2DRL L={L:2d}: reward(last10)={r[-10:].mean():9.2f} "
                  f"hit={ev['hit_ratio']:.3f} G={ev['utility']:.2f} "
                  f"[{ev['train_s']}s]", flush=True)

    # Fig 6b: DDPG baseline
    hist, ev = train_and_eval("ddpg", env=env, episodes=episodes, seed=seed,
                              num_envs=num_envs)
    r = np.asarray(hist["episode_reward"])
    out["curves"]["ddpg"] = history_to_list(hist)
    out["ddpg"] = {**reward_summary(r), **ev}
    if verbose:
        print(f"DDPG      : reward(last10)={r[-10:].mean():9.2f} "
              f"hit={ev['hit_ratio']:.3f} G={ev['utility']:.2f} "
              f"[{ev['train_s']}s]", flush=True)

    save_json("convergence.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episodes", type=int, default=150)
    ap.add_argument("--Ls", type=int, nargs="+", default=[1, 5, 10])
    ap.add_argument("--num-envs", type=int, default=1,
                    help="parallel cells (multi-seed) per method")
    args = ap.parse_args()
    run(args.episodes, tuple(args.Ls), num_envs=args.num_envs)


if __name__ == "__main__":
    main()
