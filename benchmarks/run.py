"""Benchmark harness entry point — one bench per paper table/figure.

  python -m benchmarks.run             # quick pass (CI scale)
  python -m benchmarks.run --full      # paper-scale episode counts
  python -m benchmarks.run --only runtime,roofline
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="",
                    help="comma list: convergence,users,cache,runtime,"
                         "roofline,scenarios,fleet,population")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-scale smoke: runtime runs the throughput "
                         "floor + independent fused gates, population "
                         "runs the one-compile 16-member sweep, cache "
                         "runs the DDQN-vs-classical cacher scoreboard")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    episodes = 500 if args.full else 60

    def want(name):
        return only is None or name in only

    t0 = time.time()
    if want("runtime"):
        from . import bench_runtime
        if args.smoke:
            print("== runtime smoke: throughput floor + fused gates ==",
                  flush=True)
            bench_runtime.run_smoke()
        else:
            print("== Table 3: per-slot running time ==", flush=True)
            bench_runtime.run(users=(10, 12, 14, 16, 18))
            print("\n== vector-env training throughput ==", flush=True)
            bench_runtime.run_throughput((1, 8), episodes=4)
    if want("population"):
        from . import bench_population
        if args.smoke:
            print("\n== population smoke: 16-member sweep, one compile ==",
                  flush=True)
            bench_population.run_smoke()
        else:
            print("\n== population sweep: fused hyperparameter grid ==",
                  flush=True)
            bench_population.run(episodes=episodes if args.full else 40)
    if want("roofline"):
        print("\n== §Roofline: dry-run table ==", flush=True)
        from . import bench_roofline
        bench_roofline.run()
    if want("convergence"):
        print("\n== Fig 6: convergence ==", flush=True)
        from . import bench_convergence
        bench_convergence.run(episodes=episodes,
                              Ls=(1, 5, 10) if not args.full
                              else (1, 5, 10, 20))
    if want("users"):
        print("\n== Fig 7: users sweep ==", flush=True)
        from . import bench_users
        bench_users.run(users=(10, 14, 18) if not args.full
                        else (10, 12, 14, 16, 18), episodes=episodes)
    if want("cache"):
        from . import bench_cache
        if args.smoke:
            print("\n== cache smoke: DDQN vs classical cacher scoreboard ==",
                  flush=True)
            bench_cache.run_smoke()
        else:
            print("\n== Fig 8: cache sweep ==", flush=True)
            bench_cache.run(capacities=(20.0, 26.0, 32.0) if not args.full
                            else (20.0, 23.0, 26.0, 29.0, 32.0),
                            episodes=episodes)
    if want("scenarios"):
        print("\n== scenario registry: workloads x methods ==", flush=True)
        from . import bench_scenarios
        bench_scenarios.run(episodes=episodes, num_envs=2 if not args.full
                            else 4)
    if want("fleet"):
        print("\n== fleet twin: request-level tail latency ==", flush=True)
        from . import bench_fleet
        bench_fleet.run(scenarios=("all",) if args.full
                        else ("paper-default", "flash-crowd"),
                        episodes=episodes,
                        num_cells=4 if args.full else 2)
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s "
          f"(results in experiments/bench/)")


if __name__ == "__main__":
    main()
