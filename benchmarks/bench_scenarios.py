"""Scenario × method evaluation harness (ROADMAP: "open a new workload").

Sweeps registered workload scenarios (repro.scenarios) against the method
suite through the vectorized training core and emits per-scenario
reward / quality / latency breakdowns as JSON.  Both the learned policies
(T2DRL, DDPG) and the non-learning baselines (RCARS, SCHRS) face the
identical modulated workload, so per-scenario deltas measure policy
adaptation, not workload luck.

  PYTHONPATH=src python -m benchmarks.bench_scenarios \
      --scenarios all --methods t2drl,rcars --num-envs 4

Output schema (experiments/bench/scenarios.json):

  {"episodes": E, "num_envs": B, "policy": "shared",
   "scenarios": {<scenario>: {
      "summary": str,
      "user_counts": [..] | null,
      "methods": {<method>: {
         "mean_reward": float, "episode_reward": float,
         "quality": float, "delay": float, "hit_ratio": float,
         "deadline_viol": float, "storage_viol": float, "utility": float,
         "train_s": float, "final_reward_mean_last10": float | null}}}}}
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import EnvCfg
from repro.obs import MetricWriter, ObsCfg
from repro.scenarios import build_scenario, list_scenarios

from .common import save_json, train_and_eval

METHODS = ("t2drl", "ddpg", "schrs", "rcars")


def resolve_scenarios(names) -> list:
    """Expand 'all' and validate scenario names against the registry."""
    reg = list_scenarios()
    if names in ("all", ("all",), ["all"]):
        return sorted(reg)
    names = list(names)
    for n in names:
        if n not in reg:
            raise SystemExit(f"unknown scenario {n!r}; registered: "
                             f"{', '.join(sorted(reg))}")
    return names


def run(scenarios=("all",), methods=("t2drl", "rcars"), episodes: int = 25,
        eval_episodes: int = 5, num_envs: int = 2, seed: int = 0,
        policy: str = "shared", env: EnvCfg | None = None,
        out_name: str = "scenarios.json", verbose: bool = True,
        cfg_overrides: dict | None = None, obs_out: str | None = None):
    """Sweep scenarios × methods; returns (and saves) the breakdown dict.

    ``cfg_overrides`` maps extra ``T2DRLCfg`` fields onto the learned
    methods — e.g. the exploration / learning-rate schedules
    (``eps_schedule``, ``lr_schedule``, ``lr_warmdown_episodes``,
    ``lr_end_scale``) the long-horizon convergence preset tunes
    (DESIGN.md §12).  ``obs_out``: path of a JSONL telemetry log
    (DESIGN.md §15) — enables in-scan learner diagnostics
    (``obs=ObsCfg(enabled=True)``) on the learned methods and streams
    ``train_chunk`` + per-method ``eval`` records there."""
    env = env or EnvCfg()
    cfg_overrides = dict(cfg_overrides or {})
    scenarios = resolve_scenarios(scenarios)
    for method in methods:
        if method not in METHODS:
            raise SystemExit(f"unknown method {method!r}; "
                             f"expected one of {METHODS}")
    writer = None
    if obs_out:
        writer = MetricWriter(obs_out)
        cfg_overrides.setdefault("obs", ObsCfg(enabled=True))
        writer.ensure_manifest(extra={"harness": "bench_scenarios",
                                      "episodes": episodes,
                                      "num_envs": num_envs,
                                      "policy": policy})
    reg = list_scenarios()
    out = {"episodes": episodes, "num_envs": num_envs, "policy": policy,
           "eval_episodes": eval_episodes,
           "cfg_overrides": cfg_overrides, "scenarios": {}}
    try:
        for name in scenarios:
            b = build_scenario(name, env, num_envs)
            row = {"summary": reg[name],
                   "user_counts": (None if b.user_counts is None
                                   else list(b.user_counts)),
                   "methods": {}}
            for method in methods:
                hist, ev = train_and_eval(
                    method, env=b.env, episodes=episodes,
                    eval_episodes=eval_episodes, seed=seed,
                    num_envs=num_envs, mods=b.mods,
                    user_counts=b.user_counts, policy=policy,
                    writer=writer, **cfg_overrides)
                if hist is not None:
                    r = np.asarray(hist["episode_reward"])
                    ev["final_reward_mean_last10"] = float(r[-10:].mean())
                else:
                    ev["final_reward_mean_last10"] = None
                row["methods"][method] = ev
                if writer is not None:
                    writer.write("eval", metrics=ev, scenario=name,
                                 method=method)
                if verbose:
                    print(f"{name:17s} {method:6s}: "
                          f"reward {ev['mean_reward']:8.2f} "
                          f"hit {ev['hit_ratio']:.3f} "
                          f"delay {ev['delay']:7.2f} "
                          f"quality {ev['quality']:6.2f} "
                          f"viol {ev['deadline_viol']:.3f} "
                          f"[{ev['train_s']}s]", flush=True)
            out["scenarios"][name] = row
    finally:
        if writer is not None:
            writer.close()
    path = save_json(out_name, out)
    if verbose:
        print(f"wrote {path}" + (f" and {obs_out}" if obs_out else ""))
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scenarios", default="all",
                    help="comma list of registry names, or 'all'")
    ap.add_argument("--methods", default="t2drl,rcars",
                    help=f"comma list from {METHODS}")
    ap.add_argument("--episodes", type=int, default=25)
    ap.add_argument("--eval-episodes", type=int, default=5)
    ap.add_argument("--num-envs", type=int, default=2,
                    help="parallel cells per scenario")
    ap.add_argument("--policy", default="shared",
                    choices=("independent", "shared"),
                    help="vector-env mode for the learned methods")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eps-schedule", default="linear",
                    choices=("linear", "cosine"),
                    help="epsilon/sigma decay shape (T2DRLCfg.eps_schedule)")
    ap.add_argument("--lr-schedule", default="const",
                    choices=("const", "linear", "cosine"),
                    help="actor/critic LR warmdown shape")
    ap.add_argument("--lr-warmdown-episodes", type=int, default=0,
                    help="LR warmdown horizon in episodes")
    ap.add_argument("--lr-end-scale", type=float, default=0.1,
                    help="final LR as a fraction of the initial rate")
    ap.add_argument("--obs-out", default=None,
                    help="JSONL telemetry log path; enables in-scan "
                         "learner diagnostics (DESIGN.md §15)")
    args = ap.parse_args()
    run(scenarios=args.scenarios.split(","),
        methods=args.methods.split(","), episodes=args.episodes,
        eval_episodes=args.eval_episodes, num_envs=args.num_envs,
        seed=args.seed, policy=args.policy, obs_out=args.obs_out,
        cfg_overrides=dict(eps_schedule=args.eps_schedule,
                           lr_schedule=args.lr_schedule,
                           lr_warmdown_episodes=args.lr_warmdown_episodes,
                           lr_end_scale=args.lr_end_scale))


if __name__ == "__main__":
    main()
