"""Table 3 — algorithm running time per time slot (ms) vs number of users —
plus the vectorized training-core throughput (episodes·envs/sec).

The per-slot section measures the jitted *inference* path of each allocator
on this host (CPU here, RTX A5000 in the paper — absolute numbers differ,
the ordering SCHRS >> T2DRL > DDPG is the reproduced claim).  The
throughput section measures end-to-end multi-cell training of the batched
vector-env core (DESIGN.md §6/§12) for B in {1, 8}: in shared-learner mode
the per-slot optimizer step costs the same at any B, so B=8 must beat B=1's
aggregate throughput by well over 2x even on CPU; the fully independent
multi-seed mode is measured in BOTH execution paths — the fused batched
program (DESIGN.md §13, the default) and the legacy per-learner ``vmap``
reference — so the ISSUE-6 before/after (vmap was *slower* at B=8 than
running B=1 eight times) stays pinned in runtime.json.

``--breakdown`` adds a per-stage attribution for the independent path:
compile time, rollout + replay-write time (a ``train=False`` episode runs
the identical program minus learner updates), and the update chain
(train minus rollout) — the stage the fused rewrite attacks.

Methodology: each configuration is timed over ``reps`` repetitions of one
fully-jitted ``run_training`` call (compile excluded and reported
separately) and the MINIMUM time is used — on small shared boxes the
minimum is the least-contended estimate, and the run-to-run spread is
recorded alongside.  ``run_training`` donates its train state, so every
repetition gets a fresh one (built outside the timed region).

Both sections merge into ``experiments/bench/runtime.json``.  The
throughput section also records the pre-refactor shared-learner B=8
baseline (measured at the PR-4 parent commit on the 2-core reference box
with the same min-of-N protocol) and the speedup against it.

``--smoke`` is the CI mode (2 episodes each): the shared-learner B=8
throughput floor, plus the ISSUE-6 independent-mode gates — fused B=8
must at least match the legacy vmap path (no more vmap slowdown) and hold
B=1's aggregate throughput (>=1.0x with 2+ cores; 0.85x on a single-core
box, where the update chain is compute-bound and batching has nothing to
amortize).  When more than one XLA device is visible (CI forces two via
``XLA_FLAGS=--xla_force_host_platform_device_count=2``) it also runs a
tiny ``run_training_sharded`` call so the shard_map path keeps compiling.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

import dataclasses

from repro.core import (EnvCfg, GACfg, T2DRLCfg, actor_act, env_reset,
                        ga_allocate, make_actor_schedule, make_models,
                        observe, run_training, run_training_sharded,
                        t2drl_init, t2drl_init_batch)
from repro.obs import ObsCfg, profiler_trace
from .common import OUT_DIR, save_json

# Pre-refactor (PR 3, commit ae1b38e) shared-learner B=8 throughput on the
# 2-core reference box: min of 6 repetitions of 4 episodes at the paper
# workload (U=M=T=K=10, warmup=100, tuned lr, L=5) — the baseline the
# agent-protocol episode core is gated against (ISSUE 5 acceptance: >=1.3x).
PRE_REFACTOR_SHARED_B8 = 10.65    # episodes*envs/sec

# CI floor for --smoke: well below the reference-box result so slower CI
# runners pass, far above a structural regression (e.g. losing the scan
# slimming or the sequential-runtime compile path).
SMOKE_FLOOR = 3.0                 # episodes*envs/sec, shared B=8

# The independent-mode smoke gates (ISSUE 6).  Fused B=8 must never lose
# to the legacy vmap path it replaced, and must hold B=1's aggregate
# throughput.  The B8/B1 parity gate presumes >=2 cores (the reference box
# and every GitHub runner); on a single-core box the independent update
# chain is purely compute-bound — the work grows linearly with B and
# batching has nothing left to amortize — so a small concession is
# allowed there instead of skipping the gate entirely.
FUSED_VS_VMAP_FLOOR = 1.0         # fused B=8 vs vmap B=8, always
B8_PARITY_FLOOR = 1.0             # fused B=8 vs B=1 aggregate, >=2 cores
B8_PARITY_FLOOR_1CORE = 0.85      # same gate on a single-core box


def _merge_runtime_json(payload: dict) -> str:
    """Merge ``payload`` into experiments/bench/runtime.json (both the
    per-slot and throughput sections write the same file)."""
    path = os.path.join(OUT_DIR, "runtime.json")
    existing = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = {}
    existing.update(payload)
    return save_json("runtime.json", existing)


def _time_fn(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(users=(10, 12, 14, 16, 18), seed: int = 0, verbose=True):
    """Table 3: per-slot inference time of each allocator vs U."""
    out = {"users": list(users), "ms_per_slot": {}}
    key = jax.random.PRNGKey(seed)
    for U in users:
        env = EnvCfg(U=U, M=10)
        models = make_models(key, env)
        state = env_reset(key, env)
        state = state._replace(rho=jnp.ones(env.M))
        s = observe(state, env, models)

        for method in ("t2drl", "ddpg"):
            cfg = T2DRLCfg(env=env, allocator="d3pg" if method == "t2drl"
                           else "ddpg")
            d3 = cfg.d3pg_cfg()
            sched = make_actor_schedule(d3)
            ts = t2drl_init(key, cfg)
            act = jax.jit(lambda p, s, k: actor_act(p, d3, sched, s, k))
            ms = _time_fn(act, ts["d3pg"]["actor"], s, key)
            out["ms_per_slot"][f"{method}_U{U}"] = ms

        ga = GACfg()
        ga_fn = jax.jit(lambda k, st: ga_allocate(k, st, env, models, ga))
        ms = _time_fn(ga_fn, key, state, iters=10)
        out["ms_per_slot"][f"schrs_U{U}"] = ms
        if verbose:
            g = out["ms_per_slot"]
            print(f"U={U:2d}  T2DRL {g[f't2drl_U{U}']:8.3f} ms   "
                  f"DDPG {g[f'ddpg_U{U}']:8.3f} ms   "
                  f"SCHRS {g[f'schrs_U{U}']:9.3f} ms", flush=True)
    _merge_runtime_json(out)
    return out


def _throughput_cfg(policy: str, impl: str = "fused") -> T2DRLCfg:
    """The paper workload the throughput section (and its pre-refactor
    baseline) is pinned to.  ``impl`` selects the independent-mode
    execution path (DESIGN.md §13): "fused" (the default batched program)
    or "vmap" (the legacy reference — the ISSUE-6 "before" numbers)."""
    return T2DRLCfg(env=EnvCfg(U=10, M=10, T=10, K=10), policy=policy,
                    warmup=100, lr_actor=1e-4, lr_critic=1e-3,
                    lr_ddqn=1e-3, L=5, independent_impl=impl)


def _measure(cfg: T2DRLCfg, B: int, episodes: int, reps: int, seed: int = 0,
             train: bool = True):
    """(min_seconds, all_times, compile_seconds) for one compiled
    ``run_training`` call of ``episodes`` episodes at batch ``B``.  A fresh
    train state is built per repetition (run_training donates its input);
    compile time is estimated as first call minus steady-state minimum."""
    key = jax.random.PRNGKey(seed)
    idx = jnp.arange(episodes)
    ts = t2drl_init_batch(key, cfg, B)
    jax.block_until_ready(ts)
    t0 = time.perf_counter()
    jax.block_until_ready(run_training(ts, cfg, key, idx, train=train))
    first_call_s = time.perf_counter() - t0                  # compile + run
    times = []
    for _ in range(reps):
        ts = t2drl_init_batch(key, cfg, B)
        jax.block_until_ready(ts)
        t0 = time.perf_counter()
        _, stats = run_training(ts, cfg, key, idx, train=train)
        jax.block_until_ready(stats)
        times.append(time.perf_counter() - t0)
    return min(times), times, max(0.0, first_call_s - min(times))


def run_throughput(num_envs=(1, 8), episodes: int = 4, seed: int = 0,
                   policies=("shared", "independent"), reps: int = 4,
                   verbose=True):
    """Vector-env training throughput: episodes·envs/sec for B parallel
    edge cells, one fully-jitted ``run_training`` call per repetition
    (compile excluded, min over ``reps``; the paper's U=M=T=K=10 setup)."""
    out = {"episodes": episodes, "reps": reps, "throughput": {},
           "compile_s": {}, "spread_s": {},
           "host": {"cpu_count": os.cpu_count(),
                    "device_count": jax.device_count()}}
    for policy in policies:
        cfg = _throughput_cfg(policy)
        for B in num_envs:
            dt, times, compile_s = _measure(cfg, B, episodes, reps, seed)
            thr = episodes * B / dt
            out["throughput"][f"{policy}_B{B}"] = thr
            out["compile_s"][f"{policy}_B{B}"] = compile_s
            out["spread_s"][f"{policy}_B{B}"] = [round(t, 3) for t in times]
            if verbose:
                print(f"{policy:12s} B={B}: min {dt:6.2f}s for {episodes} "
                      f"eps -> {thr:7.2f} ep*envs/s "
                      f"(compile {compile_s:.1f}s, "
                      f"spread {min(times):.2f}-{max(times):.2f}s)",
                      flush=True)
        if len(num_envs) > 1:
            b_lo, b_hi = min(num_envs), max(num_envs)
            lo = out["throughput"][f"{policy}_B{b_lo}"]
            hi = out["throughput"][f"{policy}_B{b_hi}"]
            out["throughput"][f"{policy}_speedup"] = hi / lo
            if verbose:
                print(f"{policy:12s} aggregate speedup B={b_hi} vs "
                      f"B={b_lo}: {hi / lo:.2f}x", flush=True)
        if policy == "independent":
            # the ISSUE-6 "before": the legacy per-learner vmap program at
            # the largest B (B=1 bypasses to the same single-learner
            # program in both impls, so only the batched point differs)
            b_hi = max(num_envs)
            vcfg = _throughput_cfg("independent", impl="vmap")
            dt, times, compile_s = _measure(vcfg, b_hi, episodes, reps, seed)
            thr = episodes * b_hi / dt
            out["throughput"][f"independent_vmap_B{b_hi}"] = thr
            out["compile_s"][f"independent_vmap_B{b_hi}"] = compile_s
            out["spread_s"][f"independent_vmap_B{b_hi}"] = [
                round(t, 3) for t in times]
            fused = out["throughput"][f"independent_B{b_hi}"]
            out["throughput"][f"independent_fused_vs_vmap_B{b_hi}"] = (
                fused / thr)
            if verbose:
                print(f"{'indep vmap':12s} B={b_hi}: min {dt:6.2f}s for "
                      f"{episodes} eps -> {thr:7.2f} ep*envs/s "
                      f"(compile {compile_s:.1f}s)", flush=True)
                print(f"{'independent':12s} fused vs vmap at B={b_hi}: "
                      f"{fused / thr:.2f}x", flush=True)
    # always (re)write the baseline keys so a rerun with different episode
    # counts can't leave a stale speedup next to fresh throughput numbers;
    # the comparison is only valid under the baseline's exact protocol
    # (4 episodes — warmup amortization changes per-episode throughput)
    out["pre_refactor_shared_B8"] = PRE_REFACTOR_SHARED_B8
    if "shared_B8" in out["throughput"] and episodes == 4:
        out["speedup_vs_pre_refactor"] = (
            out["throughput"]["shared_B8"] / PRE_REFACTOR_SHARED_B8)
        if verbose:
            print(f"shared B=8 vs pre-refactor baseline "
                  f"({PRE_REFACTOR_SHARED_B8:.2f}): "
                  f"{out['speedup_vs_pre_refactor']:.2f}x", flush=True)
    else:
        # different episode count than the baseline protocol: incomparable
        out["speedup_vs_pre_refactor"] = None
    _merge_runtime_json(out)
    save_json("throughput.json", out)   # legacy location, same payload
    return out


def run_breakdown(num_envs=(1, 8), episodes: int = 4, reps: int = 3,
                  seed: int = 0, impls=("fused", "vmap"), verbose=True):
    """Per-stage timing attribution for the independent training path.

    Stages (per configuration, min over ``reps``):

    - ``compile_s``   — first jitted call minus steady state, per program
    - ``rollout_s``   — a full ``train=False`` episode batch: env stepping,
      acting, and replay writes (the stores run unconditionally in the
      episode scan; only learner updates are gated out), i.e. everything
      EXCEPT the update chain
    - ``train_s``     — the full ``train=True`` program
    - ``update_s``    — train minus rollout: the learner-update chain the
      fused batching rewrite attacks

    Writes a ``breakdown`` section into runtime.json keyed
    ``independent[_vmap]_B{n}``."""
    out = {"breakdown": {"episodes": episodes, "reps": reps,
                         "host": {"cpu_count": os.cpu_count(),
                                  "device_count": jax.device_count()}}}
    rows = out["breakdown"]
    for impl in impls:
        cfg = _throughput_cfg("independent", impl=impl)
        tag = "independent" if impl == "fused" else "independent_vmap"
        for B in num_envs:
            if impl == "vmap" and B == min(num_envs) and len(num_envs) > 1:
                continue   # B=1 bypasses to the same program in both impls
            roll, _, c_roll = _measure(cfg, B, episodes, reps, seed,
                                       train=False)
            full, _, c_full = _measure(cfg, B, episodes, reps, seed,
                                       train=True)
            upd = max(0.0, full - roll)
            rows[f"{tag}_B{B}"] = {
                "compile_s": round(c_full, 2),
                "compile_rollout_s": round(c_roll, 2),
                "rollout_s": round(roll, 3),
                "train_s": round(full, 3),
                "update_s": round(upd, 3),
                "update_frac": round(upd / full, 3) if full else None,
            }
            if verbose:
                r = rows[f"{tag}_B{B}"]
                print(f"{tag:18s} B={B}: compile {r['compile_s']:5.1f}s  "
                      f"rollout {r['rollout_s']:6.2f}s  "
                      f"train {r['train_s']:6.2f}s  "
                      f"update {r['update_s']:6.2f}s "
                      f"({100 * r['update_frac']:.0f}% of train)",
                      flush=True)
    _merge_runtime_json(out)
    return out


def run_obs_overhead(episodes: int = 4, reps: int = 3, seed: int = 0,
                     trace_dir: str | None = None, verbose=True) -> dict:
    """Telemetry cost: the fully-tapped in-scan diagnostics program
    (``obs=ObsCfg(enabled=True)``, DESIGN.md §15) vs the identical
    telemetry-off training run, at B=1 on the paper workload.  The ISSUE-8
    acceptance bound is <5% wall-clock overhead.  ``trace_dir`` wraps the
    telemetry-on measurement in a ``jax.profiler`` trace.

    Writes an ``obs_overhead`` section into runtime.json."""
    base = _throughput_cfg("independent")            # obs off by default
    tapped = dataclasses.replace(base, obs=ObsCfg(enabled=True))
    t_off, off_times, c_off = _measure(base, 1, episodes, reps, seed)
    with profiler_trace(trace_dir):
        t_on, on_times, c_on = _measure(tapped, 1, episodes, reps, seed)
    overhead = t_on / t_off - 1.0
    out = {"obs_overhead": {
        "episodes": episodes, "reps": reps,
        "off_s": round(t_off, 3), "on_s": round(t_on, 3),
        "off_spread_s": [round(t, 3) for t in off_times],
        "on_spread_s": [round(t, 3) for t in on_times],
        "compile_off_s": round(c_off, 2), "compile_on_s": round(c_on, 2),
        "overhead_frac": round(overhead, 4),
        "host": {"cpu_count": os.cpu_count(),
                 "device_count": jax.device_count()}}}
    if verbose:
        print(f"obs overhead: off {t_off:.2f}s, on {t_on:.2f}s -> "
              f"{100 * overhead:+.1f}% (acceptance < +5%)", flush=True)
        if trace_dir:
            print(f"profiler trace written under {trace_dir}", flush=True)
    _merge_runtime_json(out)
    return out


def run_smoke(floor: float = SMOKE_FLOOR, episodes: int = 2, reps: int = 2,
              verbose=True) -> dict:
    """CI gates, all on the same 2-episode compiled paths the full bench
    measures:

    1. shared-learner B=8 throughput above ``floor`` (absolute);
    2. independent fused B=8 at least ``FUSED_VS_VMAP_FLOOR``x the legacy
       vmap program — the ISSUE-6 regression gate (vmap at B=8 used to run
       ~0.6x of B=1's aggregate; the fused path must never fall back);
    3. independent fused B=8 aggregate throughput at parity with B=1
       (``B8_PARITY_FLOOR``) when the host has 2+ cores; on a 1-core box
       the compute-bound update chain makes parity unattainable and the
       relaxed ``B8_PARITY_FLOOR_1CORE`` applies;
    4. when >1 XLA device is visible (CI forces 2 host devices), one tiny
       ``run_training_sharded`` call so the shard_map placement path keeps
       compiling.

    Writes the results into runtime.json; raises SystemExit on any gate."""
    failures = []
    cfg = _throughput_cfg("shared")
    dt, times, compile_s = _measure(cfg, 8, episodes, reps)
    thr = episodes * 8 / dt
    smoke = {"shared_B8": thr, "compile_s": compile_s,
             "episodes": episodes, "floor": floor,
             "spread_s": [round(t, 3) for t in times],
             "host": {"cpu_count": os.cpu_count(),
                      "device_count": jax.device_count()}}
    if verbose:
        print(f"smoke: shared B=8 {thr:.2f} ep*envs/s "
              f"(floor {floor}, compile {compile_s:.1f}s)", flush=True)
    if thr < floor:
        failures.append(f"shared B=8 {thr:.2f} ep*envs/s below the pinned "
                        f"floor {floor}")

    # ISSUE-6 independent-mode gates: fused vs vmap at B=8, fused B8 vs B1.
    fused = _throughput_cfg("independent")
    b1, _, _ = _measure(fused, 1, episodes, reps)
    b8, _, _ = _measure(fused, 8, episodes, reps)
    v8, _, _ = _measure(_throughput_cfg("independent", impl="vmap"),
                        8, episodes, reps)
    thr_b1, thr_b8, thr_v8 = (episodes / b1, episodes * 8 / b8,
                              episodes * 8 / v8)
    vs_vmap, vs_b1 = thr_b8 / thr_v8, thr_b8 / thr_b1
    parity_floor = (B8_PARITY_FLOOR if (os.cpu_count() or 1) >= 2
                    else B8_PARITY_FLOOR_1CORE)
    smoke.update(independent_B1=thr_b1, independent_B8=thr_b8,
                 independent_vmap_B8=thr_v8,
                 fused_vs_vmap_B8=vs_vmap, fused_B8_vs_B1=vs_b1,
                 parity_floor=parity_floor)
    if verbose:
        print(f"smoke: independent B=1 {thr_b1:.2f}, fused B=8 "
              f"{thr_b8:.2f}, vmap B=8 {thr_v8:.2f} ep*envs/s", flush=True)
        print(f"smoke: fused-vs-vmap {vs_vmap:.2f}x "
              f"(floor {FUSED_VS_VMAP_FLOOR}), B8-vs-B1 {vs_b1:.2f}x "
              f"(floor {parity_floor})", flush=True)
    if vs_vmap < FUSED_VS_VMAP_FLOOR:
        failures.append(f"independent fused B=8 is {vs_vmap:.2f}x the vmap "
                        f"path (floor {FUSED_VS_VMAP_FLOOR})")
    if vs_b1 < parity_floor:
        failures.append(f"independent fused B=8 aggregate is {vs_b1:.2f}x "
                        f"B=1 (floor {parity_floor})")

    # keep the shard_map placement path compiling (a small env keeps the
    # extra compile cheap; correctness vs the fused path is pinned in
    # tests/test_fused.py — this only guards "still builds and runs")
    if jax.device_count() > 1:
        scfg = dataclasses.replace(
            _throughput_cfg("independent"), env=EnvCfg(U=6, M=6, T=6, K=6),
            warmup=10)
        key = jax.random.PRNGKey(0)
        ts = t2drl_init_batch(key, scfg, jax.device_count())
        _, stats = run_training_sharded(ts, scfg, key, jnp.arange(1))
        jax.block_until_ready(stats)
        smoke["sharded_devices"] = jax.device_count()
        if verbose:
            print(f"smoke: shard_map path ran on {jax.device_count()} "
                  f"host devices", flush=True)

    _merge_runtime_json({"smoke": smoke})
    if failures:
        raise SystemExit("throughput smoke FAILED: " + "; ".join(failures))
    return {"smoke": smoke}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+",
                    default=[10, 12, 14, 16, 18])
    ap.add_argument("--num-envs", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--reps", type=int, default=4,
                    help="timed repetitions per configuration (min is used)")
    ap.add_argument("--skip-slot", action="store_true",
                    help="skip the per-slot Table 3 section")
    ap.add_argument("--skip-throughput", action="store_true",
                    help="skip the vector-env training throughput section")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: throughput floor + independent-mode "
                         "fused gates only")
    ap.add_argument("--floor", type=float, default=SMOKE_FLOOR,
                    help="episodes*envs/sec floor for --smoke")
    ap.add_argument("--breakdown", action="store_true",
                    help="per-stage timing attribution (compile / rollout+"
                         "replay-write / update) for the independent path")
    ap.add_argument("--obs-overhead", action="store_true",
                    help="telemetry-on vs telemetry-off wall-clock cost of "
                         "the in-scan diagnostics (DESIGN.md §15)")
    ap.add_argument("--trace-dir", default=None,
                    help="with --obs-overhead: write a jax.profiler trace "
                         "of the telemetry-on run under this directory")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(floor=args.floor)
        return
    if args.obs_overhead:
        run_obs_overhead(episodes=args.episodes, reps=args.reps,
                         trace_dir=args.trace_dir)
        return
    if args.breakdown:
        run_breakdown(tuple(args.num_envs), episodes=args.episodes)
        return
    if not args.skip_slot:
        run(tuple(args.users))
    if not args.skip_throughput:
        run_throughput(tuple(args.num_envs), episodes=args.episodes,
                       reps=args.reps)


if __name__ == "__main__":
    main()
