"""Table 3 — algorithm running time per time slot (ms) vs number of users —
plus the vectorized training-core throughput (episodes·envs/sec).

The per-slot section measures the jitted *inference* path of each allocator
on this host (CPU here, RTX A5000 in the paper — absolute numbers differ,
the ordering SCHRS >> T2DRL > DDPG is the reproduced claim).  The
throughput section measures end-to-end multi-cell training of the batched
vector-env core (DESIGN.md §6) for B in {1, 8}: in shared-learner mode the
per-slot optimizer step costs the same at any B, so B=8 must beat B=1's
aggregate throughput by well over 2x even on CPU; the fully independent
multi-seed mode is reported alongside for comparison."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (EnvCfg, GACfg, T2DRLCfg, actor_act, env_reset,
                        ga_allocate, make_actor_schedule, make_models,
                        observe, run_training, t2drl_init, t2drl_init_batch)
from .common import save_json


def _time_fn(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(users=(10, 12, 14, 16, 18), seed: int = 0, verbose=True):
    out = {"users": list(users), "ms_per_slot": {}}
    key = jax.random.PRNGKey(seed)
    for U in users:
        env = EnvCfg(U=U, M=10)
        models = make_models(key, env)
        state = env_reset(key, env)
        state = state._replace(rho=jnp.ones(env.M))
        s = observe(state, env, models)

        for method in ("t2drl", "ddpg"):
            cfg = T2DRLCfg(env=env, allocator="d3pg" if method == "t2drl"
                           else "ddpg")
            d3 = cfg.d3pg_cfg()
            sched = make_actor_schedule(d3)
            ts = t2drl_init(key, cfg)
            act = jax.jit(lambda p, s, k: actor_act(p, d3, sched, s, k))
            ms = _time_fn(act, ts["d3pg"]["actor"], s, key)
            out["ms_per_slot"][f"{method}_U{U}"] = ms

        ga = GACfg()
        ga_fn = jax.jit(lambda k, st: ga_allocate(k, st, env, models, ga))
        ms = _time_fn(ga_fn, key, state, iters=10)
        out["ms_per_slot"][f"schrs_U{U}"] = ms
        if verbose:
            g = out["ms_per_slot"]
            print(f"U={U:2d}  T2DRL {g[f't2drl_U{U}']:8.3f} ms   "
                  f"DDPG {g[f'ddpg_U{U}']:8.3f} ms   "
                  f"SCHRS {g[f'schrs_U{U}']:9.3f} ms", flush=True)
    save_json("runtime.json", out)
    return out


def run_throughput(num_envs=(1, 8), episodes: int = 4, seed: int = 0,
                   policies=("shared", "independent"), verbose=True):
    """Vector-env training throughput: episodes·envs/sec for B parallel
    edge cells, one fully-jitted ``run_training`` call per measurement
    (compile excluded; the paper's U=M=T=K=10 setup)."""
    out = {"episodes": episodes, "throughput": {}}
    key = jax.random.PRNGKey(seed)
    for policy in policies:
        cfg = T2DRLCfg(env=EnvCfg(U=10, M=10, T=10, K=10), policy=policy,
                       warmup=100, lr_actor=1e-4, lr_critic=1e-3,
                       lr_ddqn=1e-3, L=5)
        for B in num_envs:
            ts = t2drl_init_batch(key, cfg, B)
            idx = jnp.arange(episodes)
            jax.block_until_ready(run_training(ts, cfg, key, idx))  # compile
            t0 = time.perf_counter()
            jax.block_until_ready(run_training(ts, cfg, key, idx))
            dt = time.perf_counter() - t0
            thr = episodes * B / dt
            out["throughput"][f"{policy}_B{B}"] = thr
            if verbose:
                print(f"{policy:12s} B={B}: {dt:6.2f}s for {episodes} eps "
                      f"-> {thr:7.2f} ep*envs/s", flush=True)
        b_lo, b_hi = min(num_envs), max(num_envs)
        lo = out["throughput"][f"{policy}_B{b_lo}"]
        hi = out["throughput"][f"{policy}_B{b_hi}"]
        out["throughput"][f"{policy}_speedup"] = hi / lo
        if verbose:
            print(f"{policy:12s} aggregate speedup B={b_hi} vs B={b_lo}: "
                  f"{hi / lo:.2f}x", flush=True)
    save_json("throughput.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+",
                    default=[10, 12, 14, 16, 18])
    ap.add_argument("--num-envs", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--skip-slot", action="store_true",
                    help="skip the per-slot Table 3 section")
    ap.add_argument("--skip-throughput", action="store_true",
                    help="skip the vector-env training throughput section")
    args = ap.parse_args()
    if not args.skip_slot:
        run(tuple(args.users))
    if not args.skip_throughput:
        run_throughput(tuple(args.num_envs), episodes=args.episodes)


if __name__ == "__main__":
    main()
