"""Table 3 — algorithm running time per time slot (ms) vs number of users.

Measures the jitted per-slot *inference* path of each allocator on this host
(CPU here, RTX A5000 in the paper — absolute numbers differ, the ordering
SCHRS >> T2DRL > DDPG is the reproduced claim)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (EnvCfg, GACfg, T2DRLCfg, actor_act, env_reset,
                        ga_allocate, make_actor_schedule, make_models,
                        observe, t2drl_init)
from .common import save_json


def _time_fn(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(users=(10, 12, 14, 16, 18), seed: int = 0, verbose=True):
    out = {"users": list(users), "ms_per_slot": {}}
    key = jax.random.PRNGKey(seed)
    for U in users:
        env = EnvCfg(U=U, M=10)
        models = make_models(key, env)
        state = env_reset(key, env)
        state = state._replace(rho=jnp.ones(env.M))
        s = observe(state, env, models)

        for method in ("t2drl", "ddpg"):
            cfg = T2DRLCfg(env=env, allocator="d3pg" if method == "t2drl"
                           else "ddpg")
            d3 = cfg.d3pg_cfg()
            sched = make_actor_schedule(d3)
            ts = t2drl_init(key, cfg)
            act = jax.jit(lambda p, s, k: actor_act(p, d3, sched, s, k))
            ms = _time_fn(act, ts["d3pg"]["actor"], s, key)
            out["ms_per_slot"][f"{method}_U{U}"] = ms

        ga = GACfg()
        ga_fn = jax.jit(lambda k, st: ga_allocate(k, st, env, models, ga))
        ms = _time_fn(ga_fn, key, state, iters=10)
        out["ms_per_slot"][f"schrs_U{U}"] = ms
        if verbose:
            g = out["ms_per_slot"]
            print(f"U={U:2d}  T2DRL {g[f't2drl_U{U}']:8.3f} ms   "
                  f"DDPG {g[f'ddpg_U{U}']:8.3f} ms   "
                  f"SCHRS {g[f'schrs_U{U}']:9.3f} ms", flush=True)
    save_json("runtime.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+",
                    default=[10, 12, 14, 16, 18])
    args = ap.parse_args()
    run(tuple(args.users))


if __name__ == "__main__":
    main()
