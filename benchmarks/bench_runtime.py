"""Table 3 — algorithm running time per time slot (ms) vs number of users —
plus the vectorized training-core throughput (episodes·envs/sec).

The per-slot section measures the jitted *inference* path of each allocator
on this host (CPU here, RTX A5000 in the paper — absolute numbers differ,
the ordering SCHRS >> T2DRL > DDPG is the reproduced claim).  The
throughput section measures end-to-end multi-cell training of the batched
vector-env core (DESIGN.md §6/§12) for B in {1, 8}: in shared-learner mode
the per-slot optimizer step costs the same at any B, so B=8 must beat B=1's
aggregate throughput by well over 2x even on CPU; the fully independent
multi-seed mode is reported alongside for comparison.

Methodology: each configuration is timed over ``reps`` repetitions of one
fully-jitted ``run_training`` call (compile excluded and reported
separately) and the MINIMUM time is used — on small shared boxes the
minimum is the least-contended estimate, and the run-to-run spread is
recorded alongside.  ``run_training`` donates its train state, so every
repetition gets a fresh one (built outside the timed region).

Both sections merge into ``experiments/bench/runtime.json``.  The
throughput section also records the pre-refactor shared-learner B=8
baseline (measured at the PR-4 parent commit on the 2-core reference box
with the same min-of-N protocol) and the speedup against it.

``--smoke`` is the CI mode: shared-learner B=8 only, 2 episodes, and a
hard floor on episodes·envs/sec (exit 1 below it) so the compiled-path
throughput cannot silently regress.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import (EnvCfg, GACfg, T2DRLCfg, actor_act, env_reset,
                        ga_allocate, make_actor_schedule, make_models,
                        observe, run_training, t2drl_init, t2drl_init_batch)
from .common import OUT_DIR, save_json

# Pre-refactor (PR 3, commit ae1b38e) shared-learner B=8 throughput on the
# 2-core reference box: min of 6 repetitions of 4 episodes at the paper
# workload (U=M=T=K=10, warmup=100, tuned lr, L=5) — the baseline the
# agent-protocol episode core is gated against (ISSUE 5 acceptance: >=1.3x).
PRE_REFACTOR_SHARED_B8 = 10.65    # episodes*envs/sec

# CI floor for --smoke: well below the reference-box result so slower CI
# runners pass, far above a structural regression (e.g. losing the scan
# slimming or the sequential-runtime compile path).
SMOKE_FLOOR = 3.0                 # episodes*envs/sec, shared B=8


def _merge_runtime_json(payload: dict) -> str:
    """Merge ``payload`` into experiments/bench/runtime.json (both the
    per-slot and throughput sections write the same file)."""
    path = os.path.join(OUT_DIR, "runtime.json")
    existing = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
        except (OSError, ValueError):
            existing = {}
    existing.update(payload)
    return save_json("runtime.json", existing)


def _time_fn(fn, *args, iters: int = 50) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def run(users=(10, 12, 14, 16, 18), seed: int = 0, verbose=True):
    """Table 3: per-slot inference time of each allocator vs U."""
    out = {"users": list(users), "ms_per_slot": {}}
    key = jax.random.PRNGKey(seed)
    for U in users:
        env = EnvCfg(U=U, M=10)
        models = make_models(key, env)
        state = env_reset(key, env)
        state = state._replace(rho=jnp.ones(env.M))
        s = observe(state, env, models)

        for method in ("t2drl", "ddpg"):
            cfg = T2DRLCfg(env=env, allocator="d3pg" if method == "t2drl"
                           else "ddpg")
            d3 = cfg.d3pg_cfg()
            sched = make_actor_schedule(d3)
            ts = t2drl_init(key, cfg)
            act = jax.jit(lambda p, s, k: actor_act(p, d3, sched, s, k))
            ms = _time_fn(act, ts["d3pg"]["actor"], s, key)
            out["ms_per_slot"][f"{method}_U{U}"] = ms

        ga = GACfg()
        ga_fn = jax.jit(lambda k, st: ga_allocate(k, st, env, models, ga))
        ms = _time_fn(ga_fn, key, state, iters=10)
        out["ms_per_slot"][f"schrs_U{U}"] = ms
        if verbose:
            g = out["ms_per_slot"]
            print(f"U={U:2d}  T2DRL {g[f't2drl_U{U}']:8.3f} ms   "
                  f"DDPG {g[f'ddpg_U{U}']:8.3f} ms   "
                  f"SCHRS {g[f'schrs_U{U}']:9.3f} ms", flush=True)
    _merge_runtime_json(out)
    return out


def _throughput_cfg(policy: str) -> T2DRLCfg:
    """The paper workload the throughput section (and its pre-refactor
    baseline) is pinned to."""
    return T2DRLCfg(env=EnvCfg(U=10, M=10, T=10, K=10), policy=policy,
                    warmup=100, lr_actor=1e-4, lr_critic=1e-3,
                    lr_ddqn=1e-3, L=5)


def _measure(cfg: T2DRLCfg, B: int, episodes: int, reps: int, seed: int = 0):
    """(min_seconds, all_times, compile_seconds) for one compiled
    ``run_training`` call of ``episodes`` episodes at batch ``B``.  A fresh
    train state is built per repetition (run_training donates its input);
    compile time is estimated as first call minus steady-state minimum."""
    key = jax.random.PRNGKey(seed)
    idx = jnp.arange(episodes)
    ts = t2drl_init_batch(key, cfg, B)
    jax.block_until_ready(ts)
    t0 = time.perf_counter()
    jax.block_until_ready(run_training(ts, cfg, key, idx))   # compile + run
    first_call_s = time.perf_counter() - t0
    times = []
    for _ in range(reps):
        ts = t2drl_init_batch(key, cfg, B)
        jax.block_until_ready(ts)
        t0 = time.perf_counter()
        _, stats = run_training(ts, cfg, key, idx)
        jax.block_until_ready(stats)
        times.append(time.perf_counter() - t0)
    return min(times), times, max(0.0, first_call_s - min(times))


def run_throughput(num_envs=(1, 8), episodes: int = 4, seed: int = 0,
                   policies=("shared", "independent"), reps: int = 4,
                   verbose=True):
    """Vector-env training throughput: episodes·envs/sec for B parallel
    edge cells, one fully-jitted ``run_training`` call per repetition
    (compile excluded, min over ``reps``; the paper's U=M=T=K=10 setup)."""
    out = {"episodes": episodes, "reps": reps, "throughput": {},
           "compile_s": {}, "spread_s": {}}
    for policy in policies:
        cfg = _throughput_cfg(policy)
        for B in num_envs:
            dt, times, compile_s = _measure(cfg, B, episodes, reps, seed)
            thr = episodes * B / dt
            out["throughput"][f"{policy}_B{B}"] = thr
            out["compile_s"][f"{policy}_B{B}"] = compile_s
            out["spread_s"][f"{policy}_B{B}"] = [round(t, 3) for t in times]
            if verbose:
                print(f"{policy:12s} B={B}: min {dt:6.2f}s for {episodes} "
                      f"eps -> {thr:7.2f} ep*envs/s "
                      f"(compile {compile_s:.1f}s, "
                      f"spread {min(times):.2f}-{max(times):.2f}s)",
                      flush=True)
        if len(num_envs) > 1:
            b_lo, b_hi = min(num_envs), max(num_envs)
            lo = out["throughput"][f"{policy}_B{b_lo}"]
            hi = out["throughput"][f"{policy}_B{b_hi}"]
            out["throughput"][f"{policy}_speedup"] = hi / lo
            if verbose:
                print(f"{policy:12s} aggregate speedup B={b_hi} vs "
                      f"B={b_lo}: {hi / lo:.2f}x", flush=True)
    # always (re)write the baseline keys so a rerun with different episode
    # counts can't leave a stale speedup next to fresh throughput numbers;
    # the comparison is only valid under the baseline's exact protocol
    # (4 episodes — warmup amortization changes per-episode throughput)
    out["pre_refactor_shared_B8"] = PRE_REFACTOR_SHARED_B8
    if "shared_B8" in out["throughput"] and episodes == 4:
        out["speedup_vs_pre_refactor"] = (
            out["throughput"]["shared_B8"] / PRE_REFACTOR_SHARED_B8)
        if verbose:
            print(f"shared B=8 vs pre-refactor baseline "
                  f"({PRE_REFACTOR_SHARED_B8:.2f}): "
                  f"{out['speedup_vs_pre_refactor']:.2f}x", flush=True)
    else:
        # different episode count than the baseline protocol: incomparable
        out["speedup_vs_pre_refactor"] = None
    _merge_runtime_json(out)
    save_json("throughput.json", out)   # legacy location, same payload
    return out


def run_smoke(floor: float = SMOKE_FLOOR, episodes: int = 2, reps: int = 2,
              verbose=True) -> dict:
    """CI gate: shared-learner B=8 throughput must stay above ``floor``.

    Small enough for CI (one compile + ``reps`` timed calls), but the same
    compiled path the full bench measures.  Writes the result into
    runtime.json and raises SystemExit(1) below the floor."""
    cfg = _throughput_cfg("shared")
    dt, times, compile_s = _measure(cfg, 8, episodes, reps)
    thr = episodes * 8 / dt
    out = {"smoke": {"shared_B8": thr, "compile_s": compile_s,
                     "episodes": episodes, "floor": floor,
                     "spread_s": [round(t, 3) for t in times]}}
    _merge_runtime_json(out)
    if verbose:
        print(f"smoke: shared B=8 {thr:.2f} ep*envs/s "
              f"(floor {floor}, compile {compile_s:.1f}s)", flush=True)
    if thr < floor:
        raise SystemExit(
            f"throughput smoke FAILED: shared B=8 {thr:.2f} ep*envs/s is "
            f"below the pinned floor {floor}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+",
                    default=[10, 12, 14, 16, 18])
    ap.add_argument("--num-envs", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--episodes", type=int, default=4)
    ap.add_argument("--reps", type=int, default=4,
                    help="timed repetitions per configuration (min is used)")
    ap.add_argument("--skip-slot", action="store_true",
                    help="skip the per-slot Table 3 section")
    ap.add_argument("--skip-throughput", action="store_true",
                    help="skip the vector-env training throughput section")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: shared B=8 throughput floor gate only")
    ap.add_argument("--floor", type=float, default=SMOKE_FLOOR,
                    help="episodes*envs/sec floor for --smoke")
    args = ap.parse_args()
    if args.smoke:
        run_smoke(floor=args.floor)
        return
    if not args.skip_slot:
        run(tuple(args.users))
    if not args.skip_throughput:
        run_throughput(tuple(args.num_envs), episodes=args.episodes,
                       reps=args.reps)


if __name__ == "__main__":
    main()
