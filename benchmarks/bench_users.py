"""Fig. 7 — GenAI model hit ratio (7a) and total utility (7b) vs the number
of users, for T2DRL / DDPG-based T2DRL / SCHRS / RCARS."""
from __future__ import annotations

import argparse

from repro.core import EnvCfg
from .common import save_json, train_and_eval

METHODS = ("t2drl", "ddpg", "schrs", "rcars")


def run(users=(10, 14, 18), episodes: int = 120, seed: int = 0,
        verbose=True):
    out = {"episodes": episodes, "users": list(users), "results": {}}
    for U in users:
        env = EnvCfg(U=U, M=10, T=10, K=10)
        for method in METHODS:
            _, ev = train_and_eval(method, env=env, episodes=episodes,
                                   seed=seed)
            out["results"][f"{method}_U{U}"] = ev
            if verbose:
                print(f"U={U:2d} {method:6s}: hit={ev['hit_ratio']:.3f} "
                      f"G={ev['utility']:8.2f} reward={ev['mean_reward']:9.2f} "
                      f"[{ev['train_s']}s]", flush=True)
    save_json("users.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+", default=[10, 14, 18])
    ap.add_argument("--episodes", type=int, default=120)
    args = ap.parse_args()
    run(tuple(args.users), args.episodes)


if __name__ == "__main__":
    main()
