"""Fig. 7 — GenAI model hit ratio (7a) and total utility (7b) vs the number
of users, for T2DRL / DDPG-based T2DRL / SCHRS / RCARS.

Runs through the batched vector-env core (DESIGN.md §6): each (U, method)
point trains ``--num-envs`` multi-seed cells in ONE compiled shared-learner
run instead of serial per-seed training, so widening the sweep costs far
less wall-clock than B separate runs.  Eval metrics are means over cells;
``final_reward_seed_std`` reports the cross-cell spread of the last-10-
episode training rewards.
"""
from __future__ import annotations

import argparse

from repro.core import EnvCfg
from .common import reward_summary, save_json, train_and_eval

METHODS = ("t2drl", "ddpg", "schrs", "rcars")


def run(users=(10, 14, 18), episodes: int = 120, seed: int = 0,
        num_envs: int = 4, policy: str = "shared", verbose=True):
    out = {"episodes": episodes, "users": list(users), "num_envs": num_envs,
           "policy": policy, "results": {}}
    for U in users:
        env = EnvCfg(U=U, M=10, T=10, K=10)
        for method in METHODS:
            hist, ev = train_and_eval(method, env=env, episodes=episodes,
                                      seed=seed, num_envs=num_envs,
                                      policy=policy, share_models=True)
            if hist is not None and num_envs > 1:
                ev["final_reward_seed_std"] = reward_summary(
                    hist["episode_reward"])["final_reward_seed_std"]
            out["results"][f"{method}_U{U}"] = ev
            if verbose:
                print(f"U={U:2d} {method:6s}: hit={ev['hit_ratio']:.3f} "
                      f"G={ev['utility']:8.2f} reward={ev['mean_reward']:9.2f} "
                      f"[{ev['train_s']}s]", flush=True)
    save_json("users.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, nargs="+", default=[10, 14, 18])
    ap.add_argument("--episodes", type=int, default=120)
    ap.add_argument("--num-envs", type=int, default=4,
                    help="multi-seed cells per point, trained in one "
                         "compiled vector-env run")
    ap.add_argument("--policy", default="shared",
                    choices=("independent", "shared"))
    args = ap.parse_args()
    run(tuple(args.users), args.episodes, num_envs=args.num_envs,
        policy=args.policy)


if __name__ == "__main__":
    main()
