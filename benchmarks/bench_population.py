"""Population-based hyperparameter sweep over the fused independent core.

Trains a grid of T2DRL hyperparameter configs (epsilon schedules, actor/
critic/DDQN learning rates, reward shaping — ``repro.core.population``) as
ONE fused ``run_training`` call per static group, greedily evaluates every
member, and reports the leaderboard against the training-free RCARS
baseline on the same environment.  This is the ISSUE-6 attack on the
ROADMAP convergence gap: a 16-config sweep costs one compile plus B=16
fused training instead of 16 sequential runs.

Results land in ``experiments/bench/population.json``::

  {"n_members": 16, "episodes": ..., "groups": [...], "train_s": ...,
   "compile_s": ..., "leaderboard": [{"label": ..., "utility": ...,
   "reward": ...}, ...], "best": {...}, "rcars": {...},
   "best_vs_rcars_utility": ...}
"""
from __future__ import annotations

import time

import jax

from repro.core import EnvCfg, default_grid, rank_population, train_population

from .common import method_cfg, save_json, train_and_eval

SMOKE_ENV = EnvCfg(U=6, M=6, T=6, K=6)


def run(*, episodes: int = 40, eval_episodes: int = 4, env: EnvCfg = None,
        grid=None, seed: int = 0, smoke: bool = False,
        out_name: str = "population.json", top: int = 8):
    """Sweep ``grid`` (default: the stock 16-member grid) and report the
    best member vs RCARS.  ``smoke`` shrinks the env and episode counts to
    CI scale while keeping the full 16-member population — the one-compile
    -per-group property under test doesn't depend on episode counts."""
    if smoke:
        env = SMOKE_ENV if env is None else env
        episodes, eval_episodes = min(episodes, 4), min(eval_episodes, 2)
    env = EnvCfg() if env is None else env
    grid = default_grid() if grid is None else grid
    cfg = method_cfg("t2drl", env=env, episodes=episodes, seed=seed,
                     policy="independent")

    t0 = time.time()
    results, groups = train_population(cfg, grid, episodes=episodes,
                                       eval_episodes=eval_episodes,
                                       seed=seed, log=print)
    train_s = time.time() - t0
    ranked = rank_population(results, by="utility")

    _, rcars = train_and_eval("rcars", env=env, episodes=episodes,
                              eval_episodes=eval_episodes, seed=seed)

    leaderboard = [{"label": r["label"],
                    "utility": r["eval"]["utility"],
                    "reward": r["eval"]["episode_reward"],
                    "hit_ratio": r["eval"]["hit_ratio"]}
                   for r in ranked]
    best = leaderboard[0]
    payload = {
        "n_members": len(grid),
        "episodes": episodes,
        "eval_episodes": eval_episodes,
        "env": {"U": env.U, "M": env.M, "T": env.T, "K": env.K},
        "smoke": smoke,
        "n_compiles": len(groups),
        "groups": groups,
        "train_s": round(train_s, 1),
        "device_count": jax.device_count(),
        "leaderboard": leaderboard,
        "best": best,
        "rcars": {"utility": rcars["utility"],
                  "reward": rcars["episode_reward"],
                  "hit_ratio": rcars["hit_ratio"]},
        "best_vs_rcars_utility": best["utility"] / rcars["utility"],
    }
    path = save_json(out_name, payload)

    print(f"\npopulation sweep: {len(grid)} members, {len(groups)} "
          f"compile group(s), {train_s:.0f}s train+eval")
    print(f"{'member':44s} {'utility':>8s} {'reward':>9s} {'hit':>6s}")
    for row in leaderboard[:top]:
        print(f"{row['label']:44s} {row['utility']:8.2f} "
              f"{row['reward']:9.2f} {row['hit_ratio']:6.3f}")
    print(f"{'RCARS baseline':44s} {rcars['utility']:8.2f} "
          f"{rcars['episode_reward']:9.2f} {rcars['hit_ratio']:6.3f}")
    print(f"best vs RCARS utility: {payload['best_vs_rcars_utility']:.3f}x "
          f"-> {path}")
    return payload


def run_smoke():
    """CI gate: the full 16-member grid must sweep in ONE compiled call
    and produce a complete leaderboard."""
    payload = run(smoke=True)
    assert payload["n_members"] >= 16, payload["n_members"]
    if payload["n_compiles"] != 1:
        raise SystemExit(f"population smoke: expected 1 compile group, got "
                         f"{payload['n_compiles']}")
    if len(payload["leaderboard"]) != payload["n_members"]:
        raise SystemExit("population smoke: incomplete leaderboard")
    print("population smoke OK")
    return payload


if __name__ == "__main__":
    run()
