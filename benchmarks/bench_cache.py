"""Fig. 8 — hit ratio (8a) and total utility (8b) vs the edge server's
caching capacity C, for T2DRL / DDPG / SCHRS / RCARS."""
from __future__ import annotations

import argparse

from repro.core import EnvCfg
from .common import save_json, train_and_eval

METHODS = ("t2drl", "ddpg", "schrs", "rcars")


def run(capacities=(20.0, 26.0, 32.0), episodes: int = 120, seed: int = 0,
        verbose=True):
    out = {"episodes": episodes, "capacities": list(capacities),
           "results": {}}
    for C in capacities:
        env = EnvCfg(U=10, M=10, T=10, K=10, C=C)
        for method in METHODS:
            _, ev = train_and_eval(method, env=env, episodes=episodes,
                                   seed=seed)
            out["results"][f"{method}_C{int(C)}"] = ev
            if verbose:
                print(f"C={C:4.0f} {method:6s}: hit={ev['hit_ratio']:.3f} "
                      f"G={ev['utility']:8.2f} [{ev['train_s']}s]",
                      flush=True)
    save_json("cache.json", out)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--capacities", type=float, nargs="+",
                    default=[20.0, 26.0, 32.0])
    ap.add_argument("--episodes", type=int, default=120)
    args = ap.parse_args()
    run(tuple(args.capacities), args.episodes)


if __name__ == "__main__":
    main()
