"""Fig. 8 — hit ratio (8a) and total utility (8b) vs the edge server's
caching capacity C, for T2DRL / DDPG / SCHRS / RCARS — plus the
isolated-cacher ablation column (DESIGN.md §14): every ``cacher-*`` method
pins the allocator to RCARS so cross-method deltas measure the caching
policy alone (learned DDQN vs classical ARC / LRU / LFU / LRU-ghost vs the
static / random floors).

``run_smoke()`` is the CI gate: a tiny-env scoreboard that trains the
learned DDQN cacher against the classical hierarchy and fails (SystemExit)
on non-finite stats, on any classical cacher violating the storage
constraint (impossible by construction — unit quantization is
conservative), or on the DDQN-vs-ARC ordering drifting outside the
calibrated bands recorded in ``experiments/bench/cache.json``.
"""
from __future__ import annotations

import argparse
import math

from repro.core import EnvCfg
from .common import save_json, train_and_eval

METHODS = ("t2drl", "ddpg", "schrs", "rcars")
# learned cacher first, classical hierarchy, then the two floors
CACHER_METHODS = ("cacher-ddqn", "cacher-arc", "cacher-lru", "cacher-lfu",
                  "cacher-lru-ghost", "cacher-static", "cacher-random")
CLASSICAL = ("cacher-arc", "cacher-lru", "cacher-lfu", "cacher-lru-ghost")

SMOKE_ENV = EnvCfg(U=6, M=8, T=6, K=6, C=12.0)
SMOKE_EPISODES = 25


def run(capacities=(20.0, 26.0, 32.0), episodes: int = 120, seed: int = 0,
        verbose=True, include_cachers: bool = True):
    methods = METHODS + (CACHER_METHODS if include_cachers else ())
    out = {"episodes": episodes, "capacities": list(capacities),
           "results": {}}
    for C in capacities:
        env = EnvCfg(U=10, M=10, T=10, K=10, C=C)
        for method in methods:
            _, ev = train_and_eval(method, env=env, episodes=episodes,
                                   seed=seed)
            out["results"][f"{method}_C{int(C)}"] = ev
            if verbose:
                print(f"C={C:4.0f} {method:16s}: "
                      f"hit={ev['hit_ratio']:.3f} "
                      f"G={ev['utility']:8.2f} [{ev['train_s']}s]",
                      flush=True)
    save_json("cache.json", out)
    return out


def _gate(ok: bool, msg: str, failures: list) -> None:
    print(("PASS " if ok else "FAIL ") + msg, flush=True)
    if not ok:
        failures.append(msg)


def run_smoke(episodes: int = SMOKE_EPISODES, seed: int = 0):
    """CI scoreboard: DDQN vs the classical cache hierarchy on a tiny env.

    Gate bands were calibrated from the committed first measurement
    (experiments/bench/cache.json, smoke section) with generous margins —
    they catch sign flips and collapse, not run-to-run noise.
    """
    out = {"smoke": True, "episodes": episodes, "seed": seed,
           "env": {"U": SMOKE_ENV.U, "M": SMOKE_ENV.M, "T": SMOKE_ENV.T,
                   "K": SMOKE_ENV.K, "C": SMOKE_ENV.C},
           "methods": {}}
    for method in CACHER_METHODS:
        _, ev = train_and_eval(method, env=SMOKE_ENV, episodes=episodes,
                               seed=seed, warmup=50)
        out["methods"][method] = ev
        print(f"{method:16s}: hit={ev['hit_ratio']:.3f} "
              f"G={ev['utility']:8.2f} sviol={ev['storage_viol']:.3f} "
              f"[{ev['train_s']}s]", flush=True)

    mm = out["methods"]
    ddqn, arc = mm["cacher-ddqn"], mm["cacher-arc"]
    out["ddqn_minus_arc"] = {
        "hit_ratio": ddqn["hit_ratio"] - arc["hit_ratio"],
        "utility": ddqn["utility"] - arc["utility"],
    }

    failures: list = []
    finite = all(math.isfinite(v) for ev in mm.values()
                 for v in ev.values())
    _gate(finite, "all scoreboard stats are finite", failures)
    for method in CLASSICAL:
        _gate(mm[method]["storage_viol"] == 0.0,
              f"{method} respects the storage constraint by construction",
              failures)
    # calibrated bands — first measurement (seed 0, 25 episodes):
    #   hit: ddqn 0.542, static 0.360, random 0.252, lfu 0.247, lru 0.219,
    #        arc 0.210, lru-ghost 0.193
    #   G:   lfu 64.3, random 61.9, lru 60.9, arc 60.9, lru-ghost 60.1,
    #        static 58.4, ddqn 56.3 (penalty-based DDQN over-caches here:
    #        sviol 1.0 buys its hit-ratio lead and costs it utility)
    for method in CLASSICAL:
        _gate(mm[method]["hit_ratio"] >= 0.10,
              f"{method} hit ratio above collapse floor (>= 0.10)", failures)
        _gate(mm[method]["utility"] >= 45.0,
              f"{method} utility above collapse floor (>= 45)", failures)
    _gate(ddqn["hit_ratio"] >= 0.35,
          "learned DDQN cacher hit ratio >= 0.35", failures)
    _gate(out["ddqn_minus_arc"]["hit_ratio"] >= 0.0,
          "DDQN does not lose to ARC on hit ratio (trained, tiny env)",
          failures)
    _gate(abs(out["ddqn_minus_arc"]["utility"]) <= 30.0,
          "DDQN-vs-ARC utility delta within the calibrated band (|d|<=30)",
          failures)

    path = save_json("cache.json", out)
    print(f"wrote {path}", flush=True)
    if failures:
        raise SystemExit("cache smoke gates failed:\n  "
                         + "\n  ".join(failures))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--capacities", type=float, nargs="+",
                    default=[20.0, 26.0, 32.0])
    ap.add_argument("--episodes", type=int, default=120)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        run_smoke()
    else:
        run(tuple(args.capacities), args.episodes)


if __name__ == "__main__":
    main()
