"""Fleet twin sweep — request-level tail latency per method × scenario.

Trains each method once on the base (paper-default) workload through the
shared-learner vector-env core, checkpoints the train state, restores it,
and deploys the restored greedy policy in the request-level queueing twin
(``repro.fleet``) under every requested scenario's traffic trace.  This is
the train → save → serve pipeline the slot-level benches cannot exercise,
and it reports the metrics they cannot see: p50/p95/p99 latency,
SLO-violation / deadline-miss / drop rates, and queue backlogs.

  PYTHONPATH=src python -m benchmarks.bench_fleet \
      --scenarios paper-default,flash-crowd --methods t2drl,rcars

Output schema (experiments/bench/fleet.json):

  {"episodes": E, "num_cells": C, "fleet": {<FleetCfg fields>},
   "sustained_requests_per_min": float,   # warm re-run, compile excluded;
                                          # absent if every pair skipped
   "scenarios": {<scenario>: {
      # a method row is {"skipped": reason} when the scenario transforms
      # EnvCfg (policy network dims are fixed at train time); otherwise:
      "summary": str, "user_counts": [..] | null,
      "methods": {<method>: {
         "requests": float, "admitted": float, "dropped": float,
         "truncated": float, "drop_rate": float,
         "slo_viol_rate": float, "deadline_miss_rate": float,
         "mean_latency_s": float, "mean_wait_s": float,
         "p50_s": float, "p95_s": float, "p99_s": float,
         "mean_backlog_s": float, "peak_backlog_s": float,
         "peak_queue_depth": float, "end_backlog_s": float,
         "sim_seconds": float, "wall_s": float,
         "requests_per_min": float, "ckpt": str}}}}}
"""
from __future__ import annotations

import argparse
import dataclasses
import math
import os

import jax

from repro.checkpoint import load_train_state, save_train_state
from repro.core import EnvCfg, t2drl_init_batch, train_t2drl
from repro.fleet import FleetCfg, simulate_fleet
from repro.obs import MetricWriter
from repro.scenarios import build_scenario, list_scenarios

from .bench_scenarios import resolve_scenarios
from .common import OUT_DIR, method_cfg, save_json

METHODS = ("t2drl", "ddpg", "schrs", "rcars")


def _row(res):
    """JSON-safe slice of a ``simulate_fleet`` result: arrays dropped,
    non-finite values (empty-histogram quantiles) mapped to null so the
    output stays strict JSON."""
    drop = ("backlog_curve", "hist", "num_cells", "frames")
    row = {k: float(v) for k, v in res.items() if k not in drop}
    return {k: (v if math.isfinite(v) else None) for k, v in row.items()}


def run(scenarios=("paper-default", "flash-crowd"),
        methods=("t2drl", "rcars"), episodes: int = 25, num_cells: int = 2,
        seed: int = 0, env: EnvCfg | None = None,
        fcfg: FleetCfg = FleetCfg(), ckpt_dir: str | None = None,
        out_name: str = "fleet.json", verbose: bool = True,
        obs_out: str | None = None):
    """Train → checkpoint → restore → deploy each method across scenarios.

    ``obs_out``: path of a JSONL telemetry log (DESIGN.md §15) — streams
    per-frame ``fleet_frame`` tail-latency/drop/backlog series plus a
    ``fleet_summary`` record for every (scenario, method) deployment."""
    env = env or EnvCfg()
    scenarios = resolve_scenarios(scenarios)
    for m in methods:
        if m not in METHODS:
            raise SystemExit(f"unknown method {m!r}; expected one of "
                             f"{METHODS}")
    reg = list_scenarios()
    ckpt_dir = ckpt_dir or os.path.join(OUT_DIR, "ckpt")
    builds = {n: build_scenario(n, env, num_cells) for n in scenarios}
    out = {"episodes": episodes, "num_cells": num_cells,
           "fleet": dataclasses.asdict(fcfg),
           "scenarios": {n: {"summary": reg[n],
                             "user_counts": (
                                 None if builds[n].user_counts is None
                                 else list(builds[n].user_counts)),
                             "methods": {}} for n in scenarios}}
    writer = MetricWriter(obs_out) if obs_out else None
    last = None
    try:
        for method in methods:
            cfg = method_cfg(method, env=env, episodes=episodes, seed=seed,
                             policy="shared")
            if method in ("t2drl", "ddpg"):
                ts, _ = train_t2drl(cfg, episodes=episodes,
                                    num_envs=num_cells)
            else:
                k_init, _ = jax.random.split(jax.random.PRNGKey(cfg.seed))
                ts = t2drl_init_batch(k_init, cfg, num_cells)
            path = save_train_state(
                os.path.join(ckpt_dir, f"{method}.msgpack"), ts,
                meta={"method": method, "allocator": cfg.allocator,
                      "cacher": cfg.cacher, "policy": cfg.policy,
                      "episodes": episodes, "num_cells": num_cells,
                      "seed": seed})
            ts, _ = load_train_state(path)      # deploy from the restore
            for name in scenarios:
                b = builds[name]
                if b.env != env:
                    # policy network dims are fixed at train time; scenarios
                    # that transform the EnvCfg need a retrained policy
                    out["scenarios"][name]["methods"][method] = {
                        "skipped": "scenario transforms EnvCfg"}
                    continue
                res = simulate_fleet(ts, cfg, fcfg, num_cells=num_cells,
                                     seed=seed + 1, mods=b.mods,
                                     user_counts=b.user_counts,
                                     writer=writer,
                                     tags={"scenario": name,
                                           "method": method})
                out["scenarios"][name]["methods"][method] = dict(
                    _row(res), ckpt=path)
                last = (ts, cfg, b)
                if verbose:
                    print(f"{name:17s} {method:6s}: "
                          f"p50 {res['p50_s']:7.1f}s "
                          f"p95 {res['p95_s']:7.1f}s "
                          f"p99 {res['p99_s']:7.1f}s "
                          f"slo {res['slo_viol_rate']:.3f} "
                          f"miss {res['deadline_miss_rate']:.3f} "
                          f"drop {res['drop_rate']:.3f} "
                          f"req {res['requests']:8.0f}", flush=True)
        if last is not None:
            # warm re-run (jit cache hit) = the sustained simulation rate
            ts, cfg, b = last
            res = simulate_fleet(ts, cfg, fcfg, num_cells=num_cells,
                                 seed=seed + 1, mods=b.mods,
                                 user_counts=b.user_counts)
            out["sustained_requests_per_min"] = float(
                res["requests_per_min"])
            if verbose:
                print(f"sustained twin rate: "
                      f"{res['requests_per_min']:.3g} simulated "
                      f"requests/min")
    finally:
        if writer is not None:
            writer.close()
    path = save_json(out_name, out)
    if verbose:
        print(f"wrote {path}")
    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--scenarios", default="paper-default,flash-crowd",
                    help="comma list of registry names, or 'all'")
    ap.add_argument("--methods", default="t2drl,rcars",
                    help=f"comma list from {METHODS}")
    ap.add_argument("--episodes", type=int, default=25)
    ap.add_argument("--num-cells", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--obs-out", default=None,
                    help="JSONL telemetry log path; streams per-frame "
                         "fleet series (DESIGN.md §15)")
    args = ap.parse_args()
    run(scenarios=args.scenarios.split(","), methods=args.methods.split(","),
        episodes=args.episodes, num_cells=args.num_cells, seed=args.seed,
        obs_out=args.obs_out)


if __name__ == "__main__":
    main()
