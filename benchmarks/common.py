"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (CACHE_POLICIES, T2DRLCfg, EnvCfg, eval_t2drl,
                        t2drl_init, t2drl_init_batch, train_t2drl)
from repro.obs import run_manifest, to_jsonable

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

# Tuned learning rates used for CI-scale convergence (the paper's 1e-6 is
# reproduced in EXPERIMENTS.md but converges impractically slowly at the
# reduced episode counts used here — see DESIGN.md §8 item 1).
TUNED = dict(lr_actor=1e-4, lr_critic=1e-3, lr_ddqn=1e-3)


def method_cfg(method: str, *, env: EnvCfg, episodes: int,
               L: int = 5, **overrides) -> T2DRLCfg:
    base = dict(env=env, episodes=episodes, L=L,
                eps_decay_episodes=max(1, int(episodes * 0.6)),
                warmup=100, **TUNED)
    base.update(overrides)
    if method == "t2drl":
        return T2DRLCfg(allocator="d3pg", cacher="ddqn", **base)
    if method == "ddpg":
        return T2DRLCfg(allocator="ddpg", cacher="ddqn", **base)
    if method == "schrs":
        return T2DRLCfg(allocator="schrs", cacher="static", **base)
    if method == "rcars":
        return T2DRLCfg(allocator="rcars", cacher="random", **base)
    if method.startswith("cacher-"):
        # isolated-cacher ablation: pin the allocator to the deterministic
        # RCARS heuristic so cross-cacher deltas measure ONLY the caching
        # policy (DDQN vs the classical ARC/LRU/LFU baselines, §14)
        return T2DRLCfg(allocator="rcars", cacher=method[len("cacher-"):],
                        **base)
    raise ValueError(method)


def _needs_training(method: str) -> bool:
    """Whether eval-time state depends on a training pass: the learned
    methods, the isolated DDQN cacher, and the STATEFUL classical cachers
    (their resident set is built by replaying request streams)."""
    if method in ("t2drl", "ddpg"):
        return True
    if method.startswith("cacher-"):
        return method[len("cacher-"):] in ("ddqn",) + CACHE_POLICIES
    return False


def train_and_eval(method: str, *, env: EnvCfg, episodes: int,
                   eval_episodes: int = 5, L: int = 5, seed: int = 0,
                   num_envs: int = 1, mods=None, user_counts=None,
                   share_models: bool = False, writer=None, **overrides):
    """Train (if learning-based) then greedy-eval.  Returns (history, eval).

    ``num_envs`` trains B parallel cells through the vectorized core
    (history leaves gain a trailing (B,) axis); eval means over cells.
    ``share_models=True`` broadcasts cell 0's model zoo to every cell
    (pure multi-seed runs on one workload, e.g. the Fig. 7 sweep).
    ``mods``/``user_counts`` run a scenario (see ``repro.scenarios`` —
    pass ``build_scenario(...).mods`` / ``.user_counts`` together with its
    transformed ``.env``); both the learned methods and the SCHRS/RCARS
    baselines then face the identical modulated workload.  ``writer``: an
    optional ``repro.obs.MetricWriter`` receiving the training run's
    telemetry records (DESIGN.md §15)."""
    cfg = method_cfg(method, env=env, episodes=episodes, L=L, seed=seed,
                     **overrides)
    t0 = time.time()
    if _needs_training(method):
        ts, hist = train_t2drl(cfg, episodes=episodes, num_envs=num_envs,
                               mods=mods, user_counts=user_counts,
                               share_models=share_models, writer=writer)
    else:
        # same init-key derivation as train_t2drl, so the non-learning
        # baselines run on the SAME model zoos as the learning methods
        # (cross-method deltas then measure the algorithm, not zoo luck)
        k_init, _ = jax.random.split(jax.random.PRNGKey(cfg.seed))
        ts = (t2drl_init(k_init, cfg) if num_envs == 1
              else t2drl_init_batch(k_init, cfg, num_envs,
                                    share_models=share_models))
        hist = None
    ev = eval_t2drl(ts, cfg, episodes=eval_episodes, mods=mods,
                    user_counts=user_counts)
    ev = {k: float(v) for k, v in ev.items()}
    ev["train_s"] = round(time.time() - t0, 1)
    return hist, ev


def save_json(name: str, payload) -> str:
    """Write a benchmark result to ``OUT_DIR``.  Dict payloads are stamped
    with a run manifest (schema, run id, git sha, jax/device info — see
    ``repro.obs.run_manifest``) under ``"manifest"`` unless the caller
    already provided one, so every ``benchmarks/*.json`` /
    ``experiments/bench/*.json`` artifact records its provenance."""
    if isinstance(payload, dict):
        payload.setdefault("manifest", run_manifest())
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w") as f:
        # to_jsonable maps arrays / np scalars to JSON values and nested
        # config dataclasses (e.g. an ObsCfg inside cfg_overrides) to
        # their reprs, so any payload a bench assembles serializes
        json.dump(to_jsonable(payload), f, indent=1)
    return path


def history_to_list(hist):
    if hist is None:
        return None
    return {k: np.asarray(v).tolist() for k, v in hist.items()}


def reward_summary(r) -> dict:
    """Final-training-reward summary shared by the benches.  ``r`` is the
    ``episode_reward`` history, (episodes,) or (episodes, B); the batched
    layout adds the cross-cell (multi-seed) spread of the last-10 mean."""
    last = np.asarray(r)[-10:]
    out = {"final_reward_mean_last10": float(last.mean())}
    if last.ndim == 2:
        out["final_reward_seed_std"] = float(last.mean(axis=0).std())
    return out
