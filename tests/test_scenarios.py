"""Scenario registry + modulation hooks (DESIGN.md §9): paper-default
byte-identity pin, deterministic hook semantics, per-scenario jit/shape
checks under num_envs>1, composition, and an eval-harness smoke."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EnvCfg, SlotMod, T2DRLCfg, env_reset, eval_t2drl,
                        schedule_frame_P, schedule_slot_mod, train_t2drl)
from repro.core.env import _refresh_slot
from repro.scenarios import (ModSpec, Scenario, build_scenario, compose,
                             get_scenario, list_scenarios, make_schedule,
                             register)

KEY = jax.random.PRNGKey(0)

CFG = T2DRLCfg(env=EnvCfg(U=4, M=4, T=3, K=3), warmup=5,
               lr_actor=1e-4, lr_critic=1e-4, lr_ddqn=1e-3, L=2,
               eps_decay_episodes=4, seed=0)

ALL = sorted(list_scenarios())


def _mod(h=1.0, din=1.0, bp=0.0, bm=0):
    return SlotMod(h_scale=jnp.float32(h), din_scale=jnp.float32(din),
                   burst_prob=jnp.float32(bp), burst_model=jnp.int32(bm))


# -- paper-default pin ---------------------------------------------------------

def test_paper_default_build_is_identity():
    b = build_scenario("paper-default", CFG.env, num_envs=4)
    assert b.mods is None and b.user_counts is None and b.env == CFG.env


def test_paper_default_training_bit_identical_to_plain():
    """The scenario API with paper-default runs the byte-identical program
    (same PRNG stream, same arithmetic) as plain train_t2drl."""
    b = build_scenario("paper-default", CFG.env, num_envs=2)
    _, h0 = train_t2drl(CFG, episodes=2, num_envs=2)
    _, h1 = train_t2drl(dataclasses.replace(CFG, env=b.env), episodes=2,
                        num_envs=2, mods=b.mods, user_counts=b.user_counts)
    for k in h0:
        np.testing.assert_array_equal(np.asarray(h0[k]), np.asarray(h1[k]),
                                      err_msg=k)


# -- deterministic hook semantics ---------------------------------------------

def test_h_scale_scales_drawn_gains_exactly():
    st = env_reset(KEY, CFG.env)
    a = _refresh_slot(KEY, st, CFG.env, mod=_mod(h=1.0))
    b = _refresh_slot(KEY, st, CFG.env, mod=_mod(h=0.1))
    np.testing.assert_allclose(np.asarray(b.h), 0.1 * np.asarray(a.h),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.req), np.asarray(b.req))


def test_din_scale_scales_input_sizes_exactly():
    st = env_reset(KEY, CFG.env)
    a = _refresh_slot(KEY, st, CFG.env, mod=_mod(din=1.0))
    b = _refresh_slot(KEY, st, CFG.env, mod=_mod(din=2.5))
    np.testing.assert_allclose(np.asarray(b.d_in), 2.5 * np.asarray(a.d_in),
                               rtol=1e-6)


def test_burst_prob_one_redirects_every_request():
    st = env_reset(KEY, CFG.env)
    out = _refresh_slot(KEY, st, CFG.env, mod=_mod(bp=1.0, bm=2))
    np.testing.assert_array_equal(np.asarray(out.req), 2)
    out = _refresh_slot(KEY, st, CFG.env, mod=_mod(bp=0.0, bm=2))
    base = _refresh_slot(KEY, st, CFG.env, mod=_mod())
    np.testing.assert_array_equal(np.asarray(out.req), np.asarray(base.req))


def test_schedule_slicing_unbatched_and_batched():
    sched = make_schedule(ModSpec(burst_period=4, burst_width=2,
                                  burst_prob=0.5), CFG.env)
    S = CFG.env.T * CFG.env.K
    assert sched.h_scale.shape == (S,)
    assert sched.P_gamma.shape == (CFG.env.T, 3, 3)
    m = schedule_slot_mod(sched, 0)
    assert m.burst_prob.shape == () and float(m.burst_prob) == 0.5
    assert float(schedule_slot_mod(sched, 2).burst_prob) == 0.0
    # clamped past the horizon (the last refresh draws slot T*K)
    assert m.h_scale.shape == ()
    _ = schedule_slot_mod(sched, S + 5)
    # batched: leading (B,) cell axis on every leaf
    b = build_scenario("degraded-channel", CFG.env, num_envs=3)
    assert b.mods.h_scale.shape == (3, S)
    mb = schedule_slot_mod(b.mods, 1)
    assert mb.h_scale.shape == (3,)
    assert schedule_frame_P(b.mods, 0).shape == (3, 3, 3)
    # first ceil(0.5*3)=2 cells degraded by -10 dB
    np.testing.assert_allclose(np.asarray(b.mods.h_scale[:, 0]),
                               [0.1, 0.1, 1.0], rtol=1e-6)


def test_rotated_P_rows_are_stochastic():
    sched = make_schedule(ModSpec(diurnal_period=2, diurnal_strength=1.0),
                          CFG.env)
    P = np.asarray(sched.P_gamma)
    np.testing.assert_allclose(P.sum(axis=-1), 1.0, atol=1e-6)
    assert not np.allclose(P[1], np.asarray(CFG.env.P_gamma))


# -- every registered scenario trains under the batched core -------------------

# every scenario through the independent core; shared-learner mode on the
# three structurally distinct schedule layouts (None / batched mods+masks /
# batched mods) — the other scenarios reuse those compiled structures
_SHARED = ("paper-default", "rush-hour", "degraded-channel")


@pytest.mark.parametrize("name,policy",
                         [(n, "independent") for n in ALL]
                         + [(n, "shared") for n in _SHARED])
def test_registered_scenarios_train_batched(name, policy):
    b = build_scenario(name, CFG.env, num_envs=3)
    cfg = dataclasses.replace(CFG, env=b.env, policy=policy)
    ts, hist = train_t2drl(cfg, episodes=2, num_envs=3, mods=b.mods,
                           user_counts=b.user_counts)
    r = np.asarray(hist["episode_reward"])
    assert r.shape == (2, 3)
    assert np.all(np.isfinite(r))
    ev = eval_t2drl(ts, cfg, episodes=2, mods=b.mods,
                    user_counts=b.user_counts)
    assert np.isfinite(float(ev["episode_reward"]))


def test_scenarios_run_baselines_too():
    b = build_scenario("flash-crowd", CFG.env, num_envs=2)
    cfg = dataclasses.replace(CFG, env=b.env, allocator="rcars",
                              cacher="random")
    _, hist = train_t2drl(cfg, episodes=2, num_envs=2, mods=b.mods)
    assert np.all(np.isfinite(np.asarray(hist["episode_reward"])))


def test_flash_crowd_concentrates_requests():
    """A saturating burst (prob 1 every slot) collapses every drawn request
    onto the hot model, from the very first reset draw."""
    spec = ModSpec(burst_period=1, burst_width=1, burst_prob=1.0,
                   burst_model=3)
    sched = make_schedule(spec, CFG.env)
    st = env_reset(KEY, CFG.env, schedule_slot_mod(sched, 0))
    np.testing.assert_array_equal(np.asarray(st.req), 3)


# -- composition & registration ------------------------------------------------

def test_compose_stacks_modspecs():
    c = compose("x", "diurnal", "flash-crowd")
    spec = c.mods(ModSpec())
    assert spec.diurnal_period > 0 and spec.burst_period > 0
    assert c.user_counts is None
    c2 = compose("y", "flash-crowd", "hetero-cells")
    assert c2.user_counts is not None
    b = build_scenario(c, CFG.env, num_envs=2)
    assert b.mods is not None


def test_register_rejects_duplicates():
    with pytest.raises(ValueError):
        register(Scenario(name="paper-default", summary="dup"))
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")


def test_mismatched_cell_schedule_is_rejected():
    b = build_scenario("degraded-channel", CFG.env, num_envs=4)
    with pytest.raises(ValueError, match="built for 4 cells"):
        train_t2drl(CFG, episodes=1, num_envs=2, mods=b.mods)


def test_rush_hour_is_registered_composition():
    b = build_scenario("rush-hour", CFG.env, num_envs=4)
    assert b.mods is not None and b.user_counts is not None
    assert len(b.user_counts) == 4


# -- harness smoke -------------------------------------------------------------

def test_eval_harness_smoke(tmp_path, monkeypatch):
    import benchmarks.common as common
    from benchmarks import bench_scenarios
    monkeypatch.setattr(common, "OUT_DIR", str(tmp_path))
    out = bench_scenarios.run(
        scenarios=("paper-default", "flash-crowd"), methods=("rcars",),
        episodes=2, eval_episodes=2, num_envs=2, env=CFG.env,
        verbose=False)
    assert set(out["scenarios"]) == {"paper-default", "flash-crowd"}
    row = out["scenarios"]["flash-crowd"]["methods"]["rcars"]
    assert np.isfinite(row["mean_reward"])
    assert (tmp_path / "scenarios.json").exists()
