"""Vectorized training core (DESIGN.md §6): B=1 equivalence with the legacy
single-env episode loop, per-env replay-buffer wraparound under the leading
batch axis, multi-cell training in both vector-env modes, masked
heterogeneous user counts, and batched agent primitives."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DDQNCfg, EnvCfg, T2DRLCfg, amend_actions,
                        amend_caching, ddqn_act, ddqn_init, episode_epsilon,
                        episode_sigma, eval_t2drl, make_user_masks,
                        run_episode, t2drl_init, t2drl_init_batch,
                        train_t2drl)
from repro.core.buffers import (buffer_add, buffer_add_batch,
                                buffer_init_batch, buffer_sample_batch)

KEY = jax.random.PRNGKey(0)

CFG = T2DRLCfg(env=EnvCfg(U=4, M=4, T=3, K=3), warmup=5,
               lr_actor=1e-4, lr_critic=1e-4, lr_ddqn=1e-3, L=2,
               eps_decay_episodes=4, seed=0)


# -- B=1 equivalence with the legacy path -------------------------------------

def _legacy_train(cfg, episodes):
    """The pre-refactor train_t2drl loop: python `for` over episodes driving
    the (still public) single-env run_episode."""
    key = jax.random.PRNGKey(cfg.seed)
    k_init, key = jax.random.split(key)
    ts = t2drl_init(k_init, cfg)
    hist = []
    for ep in range(episodes):
        k_ep = jax.random.fold_in(key, ep)
        e = jnp.float32(ep)
        ts, stats = run_episode(ts, cfg, k_ep, episode_epsilon(cfg, e),
                                episode_sigma(cfg, e), train=True)
        hist.append(stats)
    return ts, {k: jnp.stack([h[k] for h in hist]) for k in hist[0]}


def test_vectorized_b1_matches_legacy_run_episode():
    ts_old, hist_old = _legacy_train(CFG, 3)
    ts_new, hist_new = train_t2drl(CFG, episodes=3, num_envs=1)
    for k in hist_old:
        np.testing.assert_allclose(np.asarray(hist_old[k]),
                                   np.asarray(hist_new[k]),
                                   rtol=1e-5, atol=1e-7, err_msg=k)
    # the train states agree too (buffers, agent params, model zoo)
    assert int(ts_new["ebuf"]["size"]) == int(ts_old["ebuf"]["size"])
    for a, b in zip(jax.tree.leaves(ts_old["d3pg"]),
                    jax.tree.leaves(ts_new["d3pg"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_vectorized_b1_history_keeps_legacy_layout():
    _, hist = train_t2drl(CFG, episodes=2, num_envs=1)
    assert np.asarray(hist["episode_reward"]).shape == (2,)


# -- per-env buffers under the leading batch axis -----------------------------

def test_batched_buffer_per_env_wraparound_and_sampling():
    B, cap = 3, 4
    buf = buffer_init_batch(B, cap, {"x": jnp.zeros(2), "y": jnp.int32(0)})
    # env b receives items 100*b + i; env 2 receives 2 extra (wraps earlier)
    for i in range(cap + 2):
        item = {"x": jnp.stack([jnp.full(2, 100.0 * b + i) for b in range(B)]),
                "y": (100 * jnp.arange(B) + i).astype(jnp.int32)}
        if i < cap:
            buf = buffer_add_batch(buf, item)
        else:
            # uneven write rates: single-env adds keep envs 0/1 untouched
            b2 = jax.tree.map(lambda x: x[2], buf)
            b2 = buffer_add(b2, jax.tree.map(lambda x: x[2], item))
            buf = jax.tree.map(lambda full, one: full.at[2].set(one), buf, b2)
    assert buf["size"].tolist() == [cap, cap, cap]
    assert buf["ptr"].tolist() == [0, 0, 2]     # env 2 wrapped 2 further
    ys = np.asarray(buf["data"]["y"])
    assert set(ys[0].tolist()) == {0, 1, 2, 3}
    assert set(ys[1].tolist()) == {100, 101, 102, 103}
    # env 2's two oldest entries were overwritten by the wrapped writes
    assert set(ys[2].tolist()) == {204, 205, 202, 203}
    batch = buffer_sample_batch(buf, jax.random.split(KEY, B), 16)
    assert batch["x"].shape == (B, 16, 2)
    for b in range(B):
        assert set(np.asarray(batch["y"][b]).tolist()) <= set(ys[b].tolist())


# -- multi-cell training ------------------------------------------------------

def test_independent_mode_trains_b_parallel_envs():
    ts, hist = train_t2drl(CFG, episodes=2, num_envs=3)
    r = np.asarray(hist["episode_reward"])
    assert r.shape == (2, 3)
    assert np.all(np.isfinite(r))
    # heterogeneous cells: independent model zoos and trajectories
    assert not np.allclose(r[:, 0], r[:, 1])
    assert not np.allclose(np.asarray(ts["models"].a1[0]),
                           np.asarray(ts["models"].a1[1]))
    # cell 0 replays the legacy key stream: first episode (pre-update
    # divergence from batched-matmul reduction order) matches B=1 exactly
    _, h1 = train_t2drl(CFG, episodes=1, num_envs=1)
    np.testing.assert_allclose(r[0, 0], np.asarray(h1["episode_reward"])[0],
                               rtol=1e-5)
    ev = eval_t2drl(ts, CFG, episodes=2)
    assert np.isfinite(float(ev["episode_reward"]))


def test_shared_mode_single_learner_all_cells():
    cfg = dataclasses.replace(CFG, policy="shared")
    ts, hist = train_t2drl(cfg, episodes=2, num_envs=3)
    r = np.asarray(hist["episode_reward"])
    assert r.shape == (2, 3)
    assert np.all(np.isfinite(r))
    # ONE set of agent parameters (no leading env axis) ...
    ref = t2drl_init(KEY, cfg)
    for a, b in zip(jax.tree.leaves(ts["d3pg"]),
                    jax.tree.leaves(ref["d3pg"])):
        assert a.shape == b.shape
    # ... but per-cell buffers and model zoos
    assert ts["ebuf"]["size"].shape == (3,)
    assert int(jnp.sum(ts["ebuf"]["size"])) == 2 * 3 * 3 * 3  # eps*T*K*B
    ev = eval_t2drl(ts, cfg, episodes=2)
    assert np.isfinite(float(ev["episode_reward"]))


def test_shared_mode_b1_roundtrip_keeps_legacy_layout():
    cfg = dataclasses.replace(CFG, policy="shared")
    ts, hist = train_t2drl(cfg, episodes=2, num_envs=1)
    assert np.asarray(hist["episode_reward"]).shape == (2,)
    assert ts["models"].a1.ndim == 1            # squeezed back
    ev = eval_t2drl(ts, cfg, episodes=2)        # re-expands internally
    assert np.isfinite(float(ev["episode_reward"]))


def test_share_models_broadcasts_one_zoo():
    ts = t2drl_init_batch(KEY, CFG, 3, share_models=True)
    a1 = np.asarray(ts["models"].a1)
    assert a1.shape[0] == 3
    np.testing.assert_array_equal(a1[0], a1[1])
    np.testing.assert_array_equal(a1[0], a1[2])


# -- heterogeneous user counts via masking ------------------------------------

def test_user_masks_zero_inactive_allocation():
    env = CFG.env
    masks = make_user_masks(env, (4, 2, 1))
    assert masks.shape == (3, env.U)
    np.testing.assert_array_equal(masks[1], [1, 1, 0, 0])
    raw = jax.random.uniform(KEY, (2 * env.U,))
    req = jnp.zeros((env.U,), jnp.int32)
    rho = jnp.ones((env.M,))
    b, xi = amend_actions(raw, req, rho, env.U, mask=masks[1])
    assert float(jnp.max(b[2:])) == 0.0 and float(jnp.max(xi[2:])) == 0.0
    assert abs(float(jnp.sum(b)) - 1.0) < 1e-4
    assert abs(float(jnp.sum(xi)) - 1.0) < 1e-4


def test_training_with_heterogeneous_user_counts():
    for policy in ("independent", "shared"):
        cfg = dataclasses.replace(CFG, policy=policy)
        _, hist = train_t2drl(cfg, episodes=2, num_envs=3,
                              user_counts=(4, 3, 2))
        assert np.all(np.isfinite(np.asarray(hist["episode_reward"])))


# -- batched agent primitives -------------------------------------------------

def test_ddqn_act_and_amender_are_batch_safe():
    cfg = DDQNCfg(M=4, J=3)
    params = ddqn_init(KEY, cfg)
    gammas = jnp.array([0, 1, 2, 0], jnp.int32)
    a = ddqn_act(params, cfg, gammas, KEY, jnp.float32(0.0))
    assert a.shape == (4,)
    # batched greedy decisions equal the per-element ones
    for i in range(4):
        ai = ddqn_act(params, cfg, gammas[i], KEY, jnp.float32(0.0))
        assert int(a[i]) == int(ai)
    rho = amend_caching(a, cfg)
    assert rho.shape == (4, cfg.M)
    for i in range(4):
        np.testing.assert_array_equal(np.asarray(rho[i]),
                                      np.asarray(amend_caching(a[i], cfg)))
