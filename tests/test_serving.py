"""Serving engine slot lifecycle (prefill bucketing, slot reuse after
EOS / budget exhaustion / context cap) and edge-gateway byte-budget
load/evict — previously only smoke-covered via test_system.py."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import lm as lm_mod
from repro.serving import CatalogEntry, EdgeGateway, Engine, ServeCfg
from repro.serving.engine import _bucket
from repro.serving.gateway import toy_diffusion_builder

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def lm():
    cfg = get_arch("qwen2-0.5b").make_smoke()
    return cfg, lm_mod.lm_init(KEY, cfg)


# -- prefill length bucketing -------------------------------------------------

def test_bucket_is_pow2_with_floor_8():
    assert _bucket(1) == 8
    assert _bucket(8) == 8
    assert _bucket(9) == 16
    assert _bucket(100) == 128


def test_admit_pads_prompt_to_bucket(lm):
    cfg, params = lm
    eng = Engine(cfg, params, ServeCfg(max_batch=2, max_seq=64))
    slot = eng.admit(7, np.arange(3, dtype=np.int32), 4)
    assert eng.pos[slot] == 8            # 3 -> bucket 8
    slot2 = eng.admit(8, np.arange(9, dtype=np.int32) % cfg.vocab, 4)
    assert eng.pos[slot2] == 16          # 9 -> bucket 16
    assert eng.slots[slot].uid == 7 and eng.slots[slot2].uid == 8


def test_bucketing_does_not_change_greedy_output(lm):
    """The same prompt admitted alone (bucket 8) and after a longer one
    (different engine state) decodes identically — padding and per-slot
    cache isolation don't leak into the logits."""
    cfg, params = lm
    prompt = np.arange(5, dtype=np.int32)
    eng_a = Engine(cfg, params, ServeCfg(max_batch=2, max_seq=64))
    done_a, _ = eng_a.run([(0, prompt, 4)])
    eng_b = Engine(cfg, params, ServeCfg(max_batch=2, max_seq=64))
    done_b, _ = eng_b.run([(0, prompt, 4),
                           (1, np.arange(12, dtype=np.int32) % cfg.vocab, 4)])
    assert done_a[0] == done_b[0]


# -- slot lifecycle -----------------------------------------------------------

def test_budget_exhaustion_frees_and_reuses_slot(lm):
    cfg, params = lm
    eng = Engine(cfg, params, ServeCfg(max_batch=1, max_seq=64))
    assert eng.free_slot() == 0
    eng.admit(0, np.arange(4, dtype=np.int32), 2)
    assert eng.free_slot() is None
    finished = []
    while not finished:
        finished = eng.step()
    (uid, toks), = finished
    assert uid == 0 and len(toks) == 3   # prefill token + 2 decode steps
    assert eng.free_slot() == 0          # slot returned to the pool
    # reuse: generation in the recycled slot matches a fresh engine
    prompt = (np.arange(6, dtype=np.int32) * 3) % cfg.vocab
    done_reuse, _ = eng.run([(1, prompt, 3)])
    fresh = Engine(cfg, params, ServeCfg(max_batch=1, max_seq=64))
    done_fresh, _ = fresh.run([(1, prompt, 3)])
    assert done_reuse[1] == done_fresh[1]


def test_eos_terminates_before_budget(lm):
    cfg, params = lm
    prompt = np.arange(4, dtype=np.int32)
    ref = Engine(cfg, params, ServeCfg(max_batch=1, max_seq=64))
    done, _ = ref.run([(0, prompt, 5)])
    first_decoded = done[0][1]           # token emitted by decode step 1
    eng = Engine(cfg, params,
                 ServeCfg(max_batch=1, max_seq=64, eos_id=first_decoded))
    done_eos, stats = eng.run([(0, prompt, 5)])
    assert done_eos[0] == done[0][:2]    # stops at the EOS token
    assert stats["decode_steps"] == 1
    assert eng.free_slot() == 0


def test_context_cap_finishes_slot(lm):
    """pos >= max_seq - 1 ends generation even with budget remaining."""
    cfg, params = lm
    eng = Engine(cfg, params, ServeCfg(max_batch=1, max_seq=16))
    done, _ = eng.run([(0, np.arange(8, dtype=np.int32), 100)])
    # pos starts at bucket 8; decode steps run pos through 9..15
    assert len(done[0]) == 8
    assert eng.free_slot() == 0


# -- gateway byte budget ------------------------------------------------------

def _catalogue(n=3, counter=None):
    def counted(seed):
        inner = toy_diffusion_builder(seed, 32)
        def build():
            if counter is not None:
                counter[seed] = counter.get(seed, 0) + 1
            return inner()
        return build
    return [CatalogEntry(model_id=i, name=f"m{i}", kind="diffusion",
                         size_gb=4.0 + i, builder=counted(i))
            for i in range(n)]


def test_gateway_load_respects_byte_budget():
    gw = EdgeGateway(_catalogue(), capacity_gb=10.0, image_dim=32,
                     total_steps=50)
    info = gw.apply_caching(np.array([1.0, 1.0, 1.0]))
    # id-order greedy: 4.0 + 5.0 fit, 6.0 would overflow -> skipped
    assert sorted(gw.loaded) == [0, 1]
    assert info["used_gb"] == pytest.approx(9.0)
    assert info["n_loaded"] == 2.0


def test_gateway_evict_then_reload_rebuilds_params():
    counter = {}
    gw = EdgeGateway(_catalogue(counter=counter), capacity_gb=6.0,
                     image_dim=32, total_steps=50)
    gw.apply_caching(np.array([1.0, 0.0, 0.0]))
    assert counter == {0: 1}
    gw.apply_caching(np.array([0.0, 1.0, 0.0]))      # evict 0, load 1
    assert sorted(gw.loaded) == [1] and gw.used_gb() == pytest.approx(5.0)
    gw.apply_caching(np.array([1.0, 0.0, 0.0]))      # reload 0 from scratch
    assert counter == {0: 2, 1: 1}
    assert 0 in gw.loaded and 1 not in gw.loaded


def test_gateway_uncached_serves_modeled_cloud_path():
    cat = _catalogue()
    gw = EdgeGateway(cat, capacity_gb=4.0, image_dim=32, total_steps=50)
    gw.apply_caching(np.array([1.0, 0.0, 0.0]))
    res = gw.serve_slot([0, 2], np.array([0.5, 0.5]), KEY)
    assert res[0].cached and res[0].measured_wall_s > 0.0
    assert not res[1].cached and res[1].measured_wall_s == 0.0
    e = cat[2]
    assert res[1].modeled_quality == e.a4
    assert res[1].modeled_delay == pytest.approx(e.b1 * e.a3 + e.b2)
