"""Launch-layer unit tests: step building on a host mesh, FSDP spec
transform, ring transform, chunked attention equivalence at the model level,
schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_arch, input_specs, make_cfg, supports
from repro.launch.steps import (PerfOpts, _apply_ring, fsdp_spec)
from repro.optim import linear_warmup_cosine

KEY = jax.random.PRNGKey(0)


class _M16:
    shape = {"data": 16, "model": 16}
    axis_names = ("data", "model")


def test_fsdp_spec_adds_data_axis_to_largest_free_dim():
    # MoE expert weight (E, d, f): E on model -> data goes on d (largest)
    s = fsdp_spec(P("model", None, None), (256, 7168, 2048), _M16())
    assert s == P("model", "data", None)
    # already data-sharded -> unchanged
    s2 = fsdp_spec(P(("data", "model"), None), (4096, 512), _M16())
    assert s2 == P(("data", "model"), None)
    # nothing divisible -> unchanged
    s3 = fsdp_spec(P(None,), (7,), _M16())
    assert s3 == P(None)


def test_ring_transform_only_touches_windowed_attention():
    arch = get_arch("qwen3-4b")
    cfg = make_cfg(arch, "long_500k")          # window=8192 variant
    rcfg = _apply_ring(cfg)
    blk = rcfg.groups[0].cycle[0]
    assert blk.attn.ring and blk.attn.window == 8192
    cfg_full = make_cfg(arch, "decode_32k")    # no window -> untouched
    rcfg2 = _apply_ring(cfg_full)
    assert not rcfg2.groups[0].cycle[0].attn.ring


def test_ring_cache_shrinks_cache_bytes():
    from repro.models.lm import lm_init_cache
    arch = get_arch("qwen3-4b")
    cfg = make_cfg(arch, "long_500k")
    sc = SHAPES["long_500k"]
    full = jax.eval_shape(lambda: lm_init_cache(cfg, 1, sc.seq_len))
    ring = jax.eval_shape(
        lambda: lm_init_cache(_apply_ring(cfg), 1, sc.seq_len))
    fb = sum(x.size for x in jax.tree.leaves(full))
    rb = sum(x.size for x in jax.tree.leaves(ring))
    assert rb * 32 < fb  # 524288 / 8192 = 64x fewer slots


def test_chunked_impl_matches_xla_at_model_level():
    from repro.models import lm as lm_mod
    cfg = get_arch("qwen2-0.5b").make_smoke()
    p = lm_mod.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 48), 0, cfg.vocab)
    l1, _ = lm_mod.lm_forward(p, cfg, toks, impl="xla",
                              compute_dtype=jnp.float32)
    l2, _ = lm_mod.lm_forward(p, cfg, toks, impl="chunked",
                              compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=2e-4, atol=2e-4)


def test_perf_opts_tags():
    assert PerfOpts().tag == "base"
    assert PerfOpts(fsdp=True, bf16_moments=True).tag == "fsdp-bf16m"
    assert PerfOpts(impl="chunked", ring=True).tag == "chunked-ring"


def test_supports_matrix_is_39_of_40():
    from repro.configs import ARCH_IDS, list_archs
    n_ok = sum(supports(a, s)[0] for a in list_archs() for s in SHAPES)
    assert n_ok == 39


def test_lr_schedule_warmup_and_decay():
    f = linear_warmup_cosine(1.0, warmup=10, steps=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) < 0.2


@pytest.mark.parametrize("arch_id,shape", [
    ("qwen2-0.5b", "train_4k"), ("mamba2-130m", "decode_32k"),
    ("deepseek-v2-236b", "prefill_32k"), ("whisper-small", "train_4k")])
def test_input_specs_are_allocation_free(arch_id, shape):
    arch = get_arch(arch_id)
    step, specs = input_specs(arch, shape)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
