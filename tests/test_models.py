"""CompositeLM model-layer tests: group scanning, shared blocks, VLM prefix,
MTP loss, remat equivalence, property tests on the loss."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import (GroupCfg, LMCfg, lm_forward, lm_init, lm_loss,
                          lm_spec, softmax_xent)
from repro.models.blocks import BlockCfg
from repro.nn.attention import AttnCfg
from repro.nn.mlp import MLPCfg

KEY = jax.random.PRNGKey(0)


def _tiny(layers=2, shared=False, remat=False):
    blk = BlockCfg(d_model=32, mixer="attn", ffn="mlp",
                   attn=AttnCfg(32, 2, 2, 16), mlp=MLPCfg(32, 64),
                   shared=shared)
    return LMCfg(name="t", vocab=64, d_model=32,
                 groups=(GroupCfg((blk,), layers),), remat=remat)


def test_scanned_params_have_leading_repeat_dim():
    cfg = _tiny(layers=3)
    p = lm_init(KEY, cfg)
    leaf = p["groups"][0]["stacked"]["0"]["mixer"]["q"]["w"]
    assert leaf.shape == (3, 32, 32)
    spec = lm_spec(cfg)
    sleaf = spec["groups"][0]["stacked"]["0"]["mixer"]["q"]["w"]
    assert sleaf[0] is None  # repeat dim unsharded


def test_shared_block_stores_single_copy():
    cfg = _tiny(layers=3, shared=True)
    p = lm_init(KEY, cfg)
    assert p["groups"][0]["stacked"] == {}
    leaf = p["groups"][0]["shared"]["0"]["mixer"]["q"]["w"]
    assert leaf.shape == (32, 32)  # no repeat dim


def test_shared_vs_unshared_param_counts():
    from repro.nn.core import count_params
    p_shared = lm_init(KEY, _tiny(layers=3, shared=True))
    p_plain = lm_init(KEY, _tiny(layers=3, shared=False))
    assert count_params(p_shared) < count_params(p_plain)


def test_remat_matches_no_remat():
    cfg = _tiny(remat=False)
    cfg_r = _tiny(remat=True)
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, 64)
    batch = {"tokens": toks, "labels": toks}
    g1 = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(p)
    g2 = jax.grad(lambda p: lm_loss(p, cfg_r, batch)[0])(p)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_vlm_prefix_embeds_change_text_logits():
    blk = BlockCfg(d_model=32, mixer="attn", ffn="mlp",
                   attn=AttnCfg(32, 2, 2, 16), mlp=MLPCfg(32, 64))
    cfg = LMCfg(name="v", vocab=64, d_model=32,
                groups=(GroupCfg((blk,), 2),), n_prefix=4,
                prefix_embed_dim=16, tie_embeddings=False)
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 8), 0, 64)
    pe1 = jax.random.normal(KEY, (1, 4, 16))
    pe2 = pe1 + 1.0
    l1, _ = lm_forward(p, cfg, toks, prefix_embeds=pe1)
    l2, _ = lm_forward(p, cfg, toks, prefix_embeds=pe2)
    assert l1.shape == (1, 12, 64)  # prefix slots prepended
    assert float(jnp.abs(l1[:, 4:] - l2[:, 4:]).max()) > 1e-3


def test_mtp_adds_loss_term():
    from repro.configs import get_arch
    cfg = get_arch("deepseek-v3-671b").make_smoke()
    assert cfg.mtp
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    loss, m = lm_loss(p, cfg, {"tokens": toks, "labels": toks})
    assert "mtp_xent" in m
    assert float(loss) > float(m["xent"])  # mtp + aux on top


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_softmax_xent_bounds_and_masking(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (2, 6, 16))
    labels = jax.random.randint(k2, (2, 6), 0, 16)
    loss = float(softmax_xent(logits, labels))
    assert loss >= 0.0
    # fully masked -> 0
    assert float(softmax_xent(logits, jnp.full((2, 6), -100))) == 0.0
    # perfect logits -> near 0
    perfect = jax.nn.one_hot(labels, 16) * 100.0
    assert float(softmax_xent(perfect, labels)) < 1e-3


def test_positions_offset_consistency_sliding_window():
    """Sliding-window forward at window=4: token t must not attend beyond 4
    back — verify by perturbing an early token."""
    blk = BlockCfg(d_model=32, mixer="attn", ffn="mlp",
                   attn=AttnCfg(32, 2, 2, 16, window=4), mlp=MLPCfg(32, 64))
    cfg = LMCfg(name="w", vocab=64, d_model=32,
                groups=(GroupCfg((blk,), 1),))
    p = lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (1, 12), 0, 64)
    toks2 = toks.at[0, 0].set((int(toks[0, 0]) + 1) % 64)
    l1, _ = lm_forward(p, cfg, toks, compute_dtype=jnp.float32)
    l2, _ = lm_forward(p, cfg, toks2, compute_dtype=jnp.float32)
    # positions >= 4 cannot see token 0 (single layer, window 4)
    np.testing.assert_allclose(np.asarray(l1[0, 4:]), np.asarray(l2[0, 4:]),
                               rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(l1[0, 0] - l2[0, 0]).max()) > 1e-4
