"""Telemetry subsystem (DESIGN.md §15): off-by-default bit-identity,
in-scan learner diagnostics, JSONL schema validation, run manifests,
the recompile counter, and the ragged-final-chunk compile pin."""
import dataclasses
import json
import warnings

import jax
import numpy as np
import pytest

from repro.core import EnvCfg, T2DRLCfg, t2drl_init, train_t2drl
from repro.fleet import FleetCfg, simulate_fleet
from repro.obs import (MetricWriter, ObsCfg, compile_events, progress_line,
                       reset_compiles, run_manifest, stage, validate_jsonl,
                       validate_record)

# Small enough for CI but busy enough that both learners actually update:
# warmup=0 opens the slot-learner gate immediately and (T-1)*episodes = 40
# stored frame transitions clear the DDQN batch-size gate (32) with room.
ENV = EnvCfg(U=3, M=3, T=5, K=2)
OBS_CFG = T2DRLCfg(env=ENV, warmup=0, lr_actor=1e-4, lr_critic=1e-3,
                   lr_ddqn=1e-3, L=2, eps_decay_episodes=8, seed=0,
                   obs=ObsCfg(enabled=True))

DIAG_KEYS = (
    # D3PG allocator taps
    "diag/actor_loss", "diag/critic_loss", "diag/actor_grad_norm",
    "diag/critic_grad_norm", "diag/q_mean", "diag/td_abs_mean",
    "diag/td_abs_max", "diag/denoise_mag", "diag/updates",
    # DDQN cacher taps
    "diag/ddqn_loss", "diag/ddqn_q_mean", "diag/ddqn_q_max",
    "diag/ddqn_td_abs_mean", "diag/ddqn_td_abs_max", "diag/ddqn_grad_norm",
    "diag/ddqn_target_div", "diag/ddqn_updates",
    # replay occupancy
    "diag/ebuf_size", "diag/ebuf_fill", "diag/fbuf_size", "diag/fbuf_fill",
)


@pytest.fixture(scope="module")
def obs_hist():
    _, hist = train_t2drl(OBS_CFG, episodes=10)
    return hist


# -- ObsCfg gating ------------------------------------------------------------

def test_obs_cfg_gating_properties():
    assert not ObsCfg().learner_on and not ObsCfg().replay_on
    on = ObsCfg(enabled=True)
    assert on.learner_on and on.replay_on
    assert not ObsCfg(enabled=True, learner=False).learner_on
    assert not ObsCfg(enabled=True, replay=False).replay_on
    # master switch dominates the per-tap flags
    assert not ObsCfg(enabled=False, learner=True).learner_on


def test_all_taps_off_is_bit_identical_to_disabled():
    """enabled=True with every tap flag off gates out all tap sites at
    the python level — the compiled program (and its history) must be
    bit-identical to obs disabled."""
    off = dataclasses.replace(OBS_CFG, obs=ObsCfg(enabled=False))
    none = dataclasses.replace(OBS_CFG, obs=ObsCfg(enabled=True,
                                                   learner=False,
                                                   replay=False))
    _, h_off = train_t2drl(off, episodes=2)
    _, h_none = train_t2drl(none, episodes=2)
    assert set(h_off) == set(h_none)
    assert not any(k.startswith("diag/") for k in h_off)
    for k in h_off:
        np.testing.assert_array_equal(np.asarray(h_off[k]),
                                      np.asarray(h_none[k]))


# -- in-scan learner diagnostics ----------------------------------------------

def test_telemetry_on_emits_learner_diagnostics(obs_hist):
    for k in DIAG_KEYS:
        assert k in obs_hist, k
        assert np.all(np.isfinite(np.asarray(obs_hist[k]))), k
    # every slot cleared the warmup gate, so the allocator updated each
    # of the T*K slots; the DDQN updates once per frame past buffer fill
    assert float(np.asarray(obs_hist["diag/updates"])[-1]) == ENV.T * ENV.K
    assert float(np.asarray(obs_hist["diag/ddqn_updates"])[-1]) > 0
    # masked maxima bound the matching means wherever an update ran
    td_mean = np.asarray(obs_hist["diag/ddqn_td_abs_mean"])
    td_max = np.asarray(obs_hist["diag/ddqn_td_abs_max"])
    did = np.asarray(obs_hist["diag/ddqn_updates"]) > 0
    assert np.all(td_max[did] >= td_mean[did] - 1e-6)
    # denoise magnitudes keep the per-denoising-step axis (L,)
    assert np.asarray(obs_hist["diag/denoise_mag"]).shape[-1] == OBS_CFG.L


def test_replay_occupancy_grows_and_respects_capacity(obs_hist):
    fill = np.asarray(obs_hist["diag/fbuf_fill"])
    size = np.asarray(obs_hist["diag/fbuf_size"])
    assert np.all(np.diff(size) >= 0)           # fills monotonically
    assert size[-1] > size[0]
    assert np.all((fill >= 0.0) & (fill <= 1.0))
    assert np.all(np.asarray(obs_hist["diag/ebuf_fill"]) <= 1.0)


def test_batched_cores_emit_per_cell_diagnostics():
    """Both vector-env modes carry diag keys with the standard leading
    (episodes, B) history layout — pooled shared-learner scalars are
    broadcast across cells, fused independent learners are per-cell."""
    for policy in ("shared", "independent"):
        cfg = dataclasses.replace(OBS_CFG, policy=policy)
        _, hist = train_t2drl(cfg, episodes=2, num_envs=2)
        for k in ("diag/updates", "diag/ddqn_loss", "diag/fbuf_size"):
            assert np.asarray(hist[k]).shape[:2] == (2, 2), (policy, k)
        mag = np.asarray(hist["diag/denoise_mag"])
        assert mag.shape == (2, 2, OBS_CFG.L), policy


# -- ragged final chunk + recompile counter -----------------------------------

def test_ragged_chunk_two_programs_and_bit_identical():
    """A log_every that does not divide episodes used to retrace a
    bespoke remainder-sized program; the fix splits the ragged tail into
    size-1 calls so a chunked run compiles exactly two training programs
    (chunk-size and 1) and stays bit-identical to the unchunked run."""
    cfg = dataclasses.replace(OBS_CFG, env=EnvCfg(U=3, M=4, T=4, K=2),
                              seed=5, obs=ObsCfg())
    _, h_ref = train_t2drl(cfg, episodes=5)
    reset_compiles()
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # no retrace warning allowed
        _, h_chunk = train_t2drl(cfg, episodes=5, log_every=2)
    ev = [e for e in compile_events() if e[0].startswith("train")]
    assert len(ev) == 2, ev                     # chunk-size + size-1 tail
    assert len({s for _, s in ev}) == 2
    assert set(h_ref) == set(h_chunk)
    for k in h_ref:
        np.testing.assert_array_equal(np.asarray(h_ref[k]),
                                      np.asarray(h_chunk[k]))


# -- schema validation --------------------------------------------------------

def test_validate_record_negatives():
    ok = {"schema": "repro-obs/1", "kind": "profile", "stage": "x",
          "wall_s": 0.1}
    validate_record(ok)
    with pytest.raises(ValueError, match="unknown schema"):
        validate_record(dict(ok, schema="repro-obs/999"))
    with pytest.raises(ValueError, match="unknown record kind"):
        validate_record(dict(ok, kind="bogus"))
    with pytest.raises(ValueError, match="missing required fields"):
        validate_record({"schema": "repro-obs/1", "kind": "train_chunk"})
    with pytest.raises(ValueError, match="JSON object"):
        validate_record([1, 2, 3])


def test_validate_jsonl_negatives(tmp_path):
    p = tmp_path / "empty.jsonl"
    p.write_text("")
    with pytest.raises(ValueError, match="empty run log"):
        validate_jsonl(str(p))
    p = tmp_path / "no_manifest.jsonl"
    p.write_text(json.dumps({"schema": "repro-obs/1", "kind": "eval",
                             "metrics": {}}) + "\n")
    with pytest.raises(ValueError, match="first record must be a manifest"):
        validate_jsonl(str(p))
    p = tmp_path / "bad_json.jsonl"
    p.write_text("{not json\n")
    with pytest.raises(ValueError, match="invalid JSON"):
        validate_jsonl(str(p))


def test_metric_writer_validates_and_is_manifest_idempotent(tmp_path):
    path = str(tmp_path / "run.jsonl")
    with MetricWriter(path) as w:
        w.ensure_manifest(OBS_CFG, extra={"note": "t"})
        w.ensure_manifest(OBS_CFG)              # no-op: already stamped
        w.write("eval", metrics={"reward": np.float32(1.5)})
        with pytest.raises(ValueError, match="unknown record kind"):
            w.write("bogus", x=1)
        with pytest.raises(ValueError, match="missing required fields"):
            w.write("train_chunk", episode=1)
    assert validate_jsonl(path) == 2
    recs = [json.loads(l) for l in open(path)]
    assert [r["kind"] for r in recs] == ["manifest", "eval"]
    assert recs[0]["cfg_hash"] and recs[0]["note"] == "t"
    assert recs[1]["metrics"]["reward"] == 1.5  # np scalars mapped to JSON


def test_run_manifest_contents():
    rec = run_manifest(OBS_CFG, extra={"harness": "test"})
    validate_record(rec)
    assert rec["kind"] == "manifest"
    assert rec["jax"] == jax.__version__
    assert rec["seed"] == OBS_CFG.seed
    assert rec["harness"] == "test"
    # cfg hash is stable and sensitive to config changes
    other = run_manifest(dataclasses.replace(OBS_CFG, seed=1))
    assert run_manifest(OBS_CFG)["cfg_hash"] == rec["cfg_hash"]
    assert other["cfg_hash"] != rec["cfg_hash"]


def test_progress_line_matches_legacy_format():
    last = {"episode_reward": -12.345, "hit_ratio": 0.5, "utility": 3.2}
    assert progress_line(7, last) == (
        "ep    7 reward    -12.35 hit 0.500 G    3.20")


def test_stage_timer_emits_profile_record(tmp_path):
    path = str(tmp_path / "prof.jsonl")
    with MetricWriter(path) as w:
        w.ensure_manifest()
        with stage("compile", writer=w, program="episode") as info:
            info["compile_s"] = 0.25
    assert validate_jsonl(path) == 2
    rec = [json.loads(l) for l in open(path)][1]
    assert rec["kind"] == "profile" and rec["stage"] == "compile"
    assert rec["wall_s"] >= 0.0 and rec["compile_s"] == 0.25
    assert rec["program"] == "episode"


# -- end-to-end run logs ------------------------------------------------------

def test_train_writer_streams_schema_valid_chunks(tmp_path):
    path = str(tmp_path / "train.jsonl")
    with MetricWriter(path) as w:
        train_t2drl(OBS_CFG, episodes=4, log_every=2, writer=w)
    n = validate_jsonl(path)
    recs = [json.loads(l) for l in open(path)]
    assert recs[0]["kind"] == "manifest"
    assert recs[0]["episodes"] == 4
    chunks = [r for r in recs if r["kind"] == "train_chunk"]
    assert [c["episode"] for c in chunks] == [2, 4]
    assert n == 1 + len(chunks)
    for c in chunks:
        assert c["wall_s"] > 0.0
        assert "episode_reward" in c["stats"]
        assert "diag/ddqn_loss" in c["stats"]   # taps ride the chunk stats
        assert len(c["stats"]["diag/denoise_mag"]) == OBS_CFG.L


def test_fleet_writer_streams_frames_and_summary(tmp_path):
    env = EnvCfg(U=4, M=4, T=3, K=3)
    cfg = T2DRLCfg(env=env, allocator="rcars", cacher="random", L=2, seed=0)
    k_init, _ = jax.random.split(jax.random.PRNGKey(cfg.seed))
    ts = t2drl_init(k_init, cfg)
    fcfg = FleetCfg(ticks_per_slot=5, arrivals_per_user_s=0.5)
    path = str(tmp_path / "fleet.jsonl")
    with MetricWriter(path) as w:
        res = simulate_fleet(ts, cfg, fcfg, num_cells=2, seed=3, writer=w,
                             tags={"scenario": "paper-default",
                                   "method": "rcars"})
    assert validate_jsonl(path) == 1 + env.T + 1
    recs = [json.loads(l) for l in open(path)]
    frames = [r for r in recs if r["kind"] == "fleet_frame"]
    assert [f["frame"] for f in frames] == list(range(env.T))
    assert all(f["method"] == "rcars" for f in frames)
    summary = [r for r in recs if r["kind"] == "fleet_summary"]
    assert len(summary) == 1
    assert summary[0]["metrics"]["requests"] == res["requests"]
    assert summary[0]["scenario"] == "paper-default"
