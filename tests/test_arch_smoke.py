"""Per-architecture smoke tests: a REDUCED same-family variant (≤2 layers,
d_model ≤ 512, ≤4 experts) runs one forward + one train step on CPU,
asserting output shapes and no NaNs — required for all 10 assigned archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import lm as lm_mod
from repro.models import whisper as wh_mod
from repro.optim import adam_init, adam_update

KEY = jax.random.PRNGKey(0)
B, L = 2, 32


def _smoke_batch(arch, cfg):
    ks = jax.random.split(KEY, 3)
    if arch.kind == "whisper":
        return {
            "frame_embeds": 0.02 * jax.random.normal(
                ks[0], (B, cfg.n_frames, cfg.d_model)),
            "tokens": jax.random.randint(ks[1], (B, L), 0, cfg.vocab),
            "labels": jax.random.randint(ks[2], (B, L), 0, cfg.vocab),
        }
    batch = {
        "tokens": jax.random.randint(ks[1], (B, L), 0, cfg.vocab),
        "labels": jax.random.randint(ks[2], (B, L), 0, cfg.vocab),
    }
    if getattr(cfg, "prefix_embed_dim", 0):
        npre = cfg.n_prefix
        batch["tokens"] = batch["tokens"][:, : L - npre]
        batch["prefix_embeds"] = 0.02 * jax.random.normal(
            ks[0], (B, npre, cfg.prefix_embed_dim))
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    arch = get_arch(arch_id)
    cfg = arch.make_smoke()
    batch = _smoke_batch(arch, cfg)
    if arch.kind == "whisper":
        params = wh_mod.whisper_init(KEY, cfg)
        logits, _ = wh_mod.whisper_forward(params, cfg,
                                           batch["frame_embeds"],
                                           batch["tokens"])
        assert logits.shape == (B, L, cfg.vocab)
        loss_fn = lambda p: wh_mod.whisper_loss(p, cfg, batch)[0]
    else:
        params = lm_mod.lm_init(KEY, cfg)
        logits, aux = lm_mod.lm_forward(
            params, cfg, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"))
        assert logits.shape == (B, L, cfg.vocab)
        loss_fn = lambda p: lm_mod.lm_loss(p, cfg, batch)[0]
    assert not bool(jnp.isnan(logits).any()), "NaN logits"

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    opt = adam_init(params)
    new_params, opt, m = adam_update(grads, opt, params, lr=1e-3)
    assert np.isfinite(float(m["gnorm"]))
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0.0


@pytest.mark.parametrize("arch_id", [i for i in ARCH_IDS
                                     if i != "whisper_small"])
def test_smoke_decode_consistency(arch_id):
    """Prefill + one decode step equals the full forward's last logits
    (MoE capacity effects excluded by high capacity in smoke configs are
    tolerated via loose rtol)."""
    arch = get_arch(arch_id)
    cfg = arch.make_smoke()
    params = lm_mod.lm_init(KEY, cfg)
    toks = jax.random.randint(KEY, (B, 12), 0, cfg.vocab)
    cache = lm_mod.lm_init_cache(cfg, B, 16, dtype=jnp.float32)
    _, cache = lm_mod.lm_prefill(params, cfg, toks, cache,
                                 compute_dtype=jnp.float32)
    lg, _ = lm_mod.lm_decode(params, cfg, toks[:, :1], cache, jnp.int32(12),
                             compute_dtype=jnp.float32)
    toks13 = jnp.concatenate([toks, toks[:, :1]], axis=1)
    full, _ = lm_mod.lm_forward(params, cfg, toks13,
                                compute_dtype=jnp.float32)
    err = float(jnp.abs(full[:, -1] - lg[:, 0]).max())
    # MoE archs see capacity-dependent token drops between the two paths
    tol = 2.0 if arch.family == "moe" else 2e-3
    assert err < tol, f"decode/full mismatch {err}"


def test_whisper_smoke_decode_consistency():
    arch = get_arch("whisper_small")
    cfg = arch.make_smoke()
    params = wh_mod.whisper_init(KEY, cfg)
    fe = 0.02 * jax.random.normal(KEY, (B, cfg.n_frames, cfg.d_model))
    toks = jax.random.randint(KEY, (B, 12), 0, cfg.vocab)
    cache = wh_mod.whisper_init_cache(cfg, B, 16, dtype=jnp.float32)
    _, cache = wh_mod.whisper_prefill(params, cfg, fe, toks, cache,
                                      compute_dtype=jnp.float32)
    lg, _ = wh_mod.whisper_decode(params, cfg, toks[:, :1], cache,
                                  jnp.int32(12), compute_dtype=jnp.float32)
    toks13 = jnp.concatenate([toks, toks[:, :1]], axis=1)
    full, _ = wh_mod.whisper_forward(params, cfg, fe, toks13,
                                     compute_dtype=jnp.float32)
    err = float(jnp.abs(full[:, -1] - lg[:, 0]).max())
    assert err < 2e-3
