"""Classical cache-hierarchy baselines (DESIGN.md §14): differential tests
against the pure-Python references plus property-based invariants.

The load-bearing contract: every jitted policy in
``repro.core.cache_policies`` must be TRACE-IDENTICAL to its reference in
``tests/_cache_refs.py`` — same hit/admitted/evicted decisions and same
resident set after every access, on randomized request/eviction streams
(sizes, capacities, invalid-access gaps all randomized).  All capacity
arithmetic is integer (size units), so the comparison is exact equality,
never approximate.

Shapes are held fixed within each sweep (M, stream length) so every policy
compiles exactly once; sizes/capacities ride as traced inputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.agents import make_cacher
from repro.agents.base import FrameObs
from repro.core import (CACHE_POLICIES, EnvCfg, T2DRLCfg, cache_access,
                        cache_rho, cache_state_init, eval_t2drl,
                        export_policy, quantize_capacity, quantize_sizes,
                        train_t2drl)
from repro.core.t2drl import _agents

# -- harness ------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=0)
def _run_trace(kind, c_units, cap_units, stream, valid):
    """Scan one request stream through a policy; returns the full decision
    trace (hit/admitted/evicted per access) and per-access resident sets."""
    def one(st_, mx):
        m, v = mx
        st_, info = cache_access(kind, st_, m, c_units, cap_units, v)
        return st_, (info, cache_rho(st_))

    state = cache_state_init(c_units.shape[0])
    state, (infos, rhos) = jax.lax.scan(one, state, (stream, valid))
    return state, infos, rhos


def _ref_trace(kind, c_units, cap_units, stream, valid):
    from _cache_refs import CACHE_REFS
    ref = CACHE_REFS[kind](len(c_units), c_units, cap_units)
    infos, rhos = [], []
    for m, v in zip(stream, valid):
        infos.append(ref.access(int(m), bool(v)))
        rhos.append(ref.rho())
    return ref, infos, rhos


def _random_case(seed, M, length):
    """One randomized request/eviction stream: item sizes, capacity, the
    request sequence, and invalid-access gaps (masked users)."""
    rng = np.random.default_rng(seed)
    c_units = rng.integers(64, 400, size=M).astype(np.int32)
    # capacity from ~1 item to most of the zoo; occasionally smaller than
    # the largest item (oversize-bypass coverage)
    cap = int(rng.integers(96, max(int(c_units.sum()), 97)))
    stream = rng.integers(0, M, size=length).astype(np.int32)
    valid = (rng.random(length) > 0.15)
    return c_units, cap, stream, valid


def _assert_trace_equal(kind, c_units, cap, stream, valid):
    state, infos, rhos = _run_trace(kind, jnp.asarray(c_units),
                                    jnp.int32(cap), jnp.asarray(stream),
                                    jnp.asarray(valid))
    ref, ref_infos, ref_rhos = _ref_trace(kind, c_units, cap, stream, valid)
    hits = np.asarray(infos["hit"])
    admits = np.asarray(infos["admitted"])
    evs = np.asarray(infos["evicted"])
    for i in range(len(stream)):
        assert bool(hits[i]) == ref_infos[i]["hit"], (kind, i)
        assert bool(admits[i]) == ref_infos[i]["admitted"], (kind, i)
        np.testing.assert_array_equal(evs[i], ref_infos[i]["evicted"],
                                      err_msg=f"{kind} access {i}")
        np.testing.assert_array_equal(np.asarray(rhos)[i], ref_rhos[i],
                                      err_msg=f"{kind} access {i}")
    # terminal state agrees leaf for leaf
    for leaf in ("in_t1", "in_t2", "in_b1", "in_b2", "freq"):
        np.testing.assert_array_equal(np.asarray(state[leaf]),
                                      getattr(ref, leaf), err_msg=kind)
    assert int(state["p"]) == ref.p
    return state, ref


# -- differential: jit vs Python reference ------------------------------------


@pytest.mark.parametrize("kind", CACHE_POLICIES)
def test_differential_traces(kind):
    """Trace identity on randomized streams (quick sweep, fixed shapes)."""
    for seed in range(12):
        c_units, cap, stream, valid = _random_case(seed, M=6, length=96)
        _assert_trace_equal(kind, c_units, cap, stream, valid)


@pytest.mark.slow
@pytest.mark.parametrize("kind", CACHE_POLICIES)
def test_differential_traces_bulk(kind):
    """>= 1000 randomized streams across the four policies (250 each);
    M and stream length fixed so each policy compiles once."""
    for seed in range(250):
        c_units, cap, stream, valid = _random_case(1_000 + seed,
                                                   M=8, length=128)
        _assert_trace_equal(kind, c_units, cap, stream, valid)


@pytest.mark.parametrize("kind", CACHE_POLICIES)
def test_differential_batched_b4(kind):
    """B=4 vmapped streams bit-match four independent references."""
    cases = [_random_case(40 + i, M=6, length=64) for i in range(4)]
    cu = jnp.stack([jnp.asarray(c) for c, _, _, _ in cases])
    cap = jnp.asarray([c for _, c, _, _ in cases], jnp.int32)
    streams = jnp.stack([jnp.asarray(s) for _, _, s, _ in cases])
    valids = jnp.stack([jnp.asarray(v) for _, _, _, v in cases])
    state, infos, rhos = jax.vmap(
        lambda c, k, s, v: _run_trace(kind, c, k, s, v))(
        cu, cap, streams, valids)
    for b, (c_units, cap_b, stream, valid) in enumerate(cases):
        ref, ref_infos, ref_rhos = _ref_trace(kind, c_units, cap_b,
                                              stream, valid)
        np.testing.assert_array_equal(
            np.asarray(infos["hit"][b]),
            np.array([i["hit"] for i in ref_infos]), err_msg=f"{kind} b{b}")
        np.testing.assert_array_equal(np.asarray(rhos[b][-1]), ref.rho(),
                                      err_msg=f"{kind} b{b}")
        for leaf in ("in_t1", "in_t2", "in_b1", "in_b2"):
            np.testing.assert_array_equal(np.asarray(state[leaf][b]),
                                          getattr(ref, leaf),
                                          err_msg=f"{kind} b{b}")


# -- property-based invariants ------------------------------------------------


@st.composite
def _stream_case(draw):
    """Hypothesis-generated request/eviction stream: zoo size, seed for
    sizes/capacity, and an explicit request list."""
    M = draw(st.integers(4, 8))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    reqs = draw(st.lists(st.integers(0, M - 1), min_size=1, max_size=48))
    return M, seed, reqs


def _case_arrays(M, seed, reqs):
    rng = np.random.default_rng(seed)
    c_units = rng.integers(64, 400, size=M).astype(np.int32)
    cap = int(rng.integers(96, max(int(c_units.sum()), 97)))
    stream = np.asarray(reqs, np.int32)
    valid = (rng.random(len(reqs)) > 0.1)
    return c_units, cap, stream, valid


@pytest.mark.parametrize("kind", CACHE_POLICIES)
@given(_stream_case())
@settings(max_examples=15, deadline=None)
def test_invariants(kind, case):
    """Capacity never exceeded, lists disjoint and bounded, p in range,
    decision flags consistent — after EVERY access of the stream."""
    M, seed, reqs = case
    c_units, cap, stream, valid = _case_arrays(M, seed, reqs)
    state, infos, rhos = _run_trace(kind, jnp.asarray(c_units),
                                    jnp.int32(cap), jnp.asarray(stream),
                                    jnp.asarray(valid))
    hit = np.asarray(infos["hit"])
    admit = np.asarray(infos["admitted"])
    ev = np.asarray(infos["evicted"])
    rhos = np.asarray(rhos)
    cu = np.asarray(c_units)
    for i in range(len(stream)):
        # capacity invariant, in exact integer units
        assert int((rhos[i] * cu).sum()) <= cap, (kind, i)
        # decisions only on valid accesses; hit and admit are exclusive
        if not valid[i]:
            assert not hit[i] and not admit[i] and not ev[i].any()
        assert not (hit[i] and admit[i])
        # evictions only happen to make room for an admission
        if ev[i].any():
            assert admit[i], (kind, i)
    # terminal structural invariants
    t1m, t2m = np.asarray(state["in_t1"]), np.asarray(state["in_t2"])
    b1m, b2m = np.asarray(state["in_b1"]), np.asarray(state["in_b2"])
    assert not (t1m & t2m).any()
    assert not ((t1m | t2m) & (b1m | b2m)).any()
    if kind == "arc":
        assert not (b1m & b2m).any()
        t1u, b1u = int(cu[t1m].sum()), int(cu[b1m].sum())
        assert t1u + b1u <= cap, "ARC: T1+B1 exceeds c"
        total = t1u + int(cu[t2m].sum()) + b1u + int(cu[b2m].sum())
        assert total <= 2 * cap, "ARC: directory exceeds 2c"
        assert 0 <= int(state["p"]) <= cap
    if kind == "lru-ghost":
        assert int(cu[b1m].sum()) <= cap, "ghost list exceeds capacity"


@pytest.mark.parametrize("kind", CACHE_POLICIES)
@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_hit_count_conservation(kind, seed):
    """Every valid access is exactly one of {hit, admitted, rejected};
    rejections only for misses that were filtered or can never fit."""
    c_units, cap, stream, valid = _random_case(seed, M=6, length=96)
    _, infos, _ = _run_trace(kind, jnp.asarray(c_units), jnp.int32(cap),
                             jnp.asarray(stream), jnp.asarray(valid))
    hit = np.asarray(infos["hit"])
    admit = np.asarray(infos["admitted"])
    n_valid = int(valid.sum())
    assert int(hit.sum()) + int((~hit & valid).sum()) == n_valid
    assert int((hit & admit).sum()) == 0
    # the ledger: hits + admissions + rejections partition valid accesses
    rejected = valid & ~hit & ~admit
    assert int(hit.sum() + admit.sum() + rejected.sum()) == n_valid
    if kind in ("lru", "lfu", "arc"):
        # non-filtered policies reject only items larger than the cache
        oversize = np.asarray(c_units)[stream] > cap
        np.testing.assert_array_equal(rejected, valid & ~hit & oversize)


@pytest.mark.parametrize("kind", CACHE_POLICIES)
def test_eviction_determinism(kind):
    """The same stream replayed twice produces identical traces and state
    (no hidden key/threading dependence)."""
    c_units, cap, stream, valid = _random_case(7, M=6, length=80)
    s1, i1, r1 = _run_trace(kind, jnp.asarray(c_units), jnp.int32(cap),
                            jnp.asarray(stream), jnp.asarray(valid))
    s2, i2, r2 = _run_trace(kind, jnp.asarray(c_units), jnp.int32(cap),
                            jnp.asarray(stream), jnp.asarray(valid))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), (s1, i1, r1), (s2, i2, r2))


def test_invalid_access_is_noop():
    """valid=False leaves every state leaf untouched (the masked-user
    lever the frame replay relies on)."""
    c_units = jnp.asarray([100, 200, 150, 120], jnp.int32)
    for kind in CACHE_POLICIES:
        state = cache_state_init(4)
        state, _ = cache_access(kind, state, jnp.int32(1), c_units, 400,
                                jnp.bool_(True))
        after, info = cache_access(kind, state, jnp.int32(2), c_units, 400,
                                   jnp.bool_(False))
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), state, after)
        assert not bool(info["hit"]) and not bool(info["admitted"])


def test_quantization_is_conservative():
    """ceil(sizes) + floor(capacity) implies unit-feasible => GB-feasible,
    so classical cachers can never trip the storage penalty (11d)."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        c = rng.uniform(2.0, 10.0, size=8).astype(np.float32)
        C = float(rng.uniform(6.0, 40.0))
        cu = np.asarray(quantize_sizes(jnp.asarray(c)))
        cap = quantize_capacity(C)
        # any subset feasible in units is feasible in GB
        sub = rng.random(8) < 0.5
        if int(cu[sub].sum()) <= cap:
            assert float(c[sub].sum()) <= C + 1e-6


# -- agent protocol + driver integration --------------------------------------

_ENV = EnvCfg(U=6, M=8, T=5, K=6)


def _cfg(cacher, **kw):
    return T2DRLCfg(env=_ENV, allocator="rcars", cacher=cacher, episodes=2,
                    seed=0, **kw)


def test_make_cacher_dispatch():
    from repro.core.ddqn import DDQNCfg
    dq = DDQNCfg(M=_ENV.M, J=len(_ENV.gammas))
    for kind in CACHE_POLICIES:
        agent = make_cacher(kind, dq, _ENV)
        assert agent.name == kind
        assert not agent.learns
        assert agent.step_frame is not None
    with pytest.raises(ValueError, match="unknown cacher"):
        make_cacher("mru", dq, _ENV)


def test_act_is_batch_transparent():
    """One act call on (B, ...) stacked cache state equals the vmapped
    per-cell act — the lockstep shared-mode contract."""
    from repro.core.env import make_models
    _, cacher = _agents(_cfg("arc"))
    key = jax.random.PRNGKey(0)
    state_b = jax.vmap(cacher.init)(jax.random.split(key, 3))
    state_b = {**state_b, "in_t1": jnp.asarray(
        [[1, 0, 0, 0, 0, 0, 0, 0], [0, 1, 1, 0, 0, 0, 0, 0],
         [0] * 8], jnp.bool_)}
    models = jax.vmap(lambda k: make_models(k, _ENV))(
        jax.random.split(key, 3))
    obs = FrameObs(jnp.asarray([0, 1, 0]), models)
    step = {"eps": jnp.float32(0.0)}
    a_b, rho_b = cacher.act(state_b, obs, key, step)
    a_v, rho_v = jax.vmap(cacher.act, in_axes=(0, 0, None, None))(
        state_b, obs, key, step)
    np.testing.assert_array_equal(np.asarray(a_b), np.asarray(a_v))
    np.testing.assert_array_equal(np.asarray(rho_b), np.asarray(rho_v))


def test_step_frame_matches_flat_stream():
    """Agent.step_frame over a (K, U) request matrix == sequential
    cache_access over the row-major flattened stream, with masked users
    replayed as no-ops."""
    from repro.core.env import make_models
    _, cacher = _agents(_cfg("lru"))
    key = jax.random.PRNGKey(3)
    models = make_models(key, _ENV)
    reqs = jax.random.randint(key, (_ENV.K, _ENV.U), 0, _ENV.M)
    mask = jnp.asarray([1, 1, 0, 1, 0, 1], jnp.float32)
    state = cacher.step_frame(cacher.init(key), reqs, models, mask)
    cu = quantize_sizes(models.c)
    cap = quantize_capacity(_ENV.C)
    ref = cache_state_init(_ENV.M)
    for k in range(_ENV.K):
        for u in range(_ENV.U):
            ref, _ = cache_access("lru", ref, reqs[k, u], cu, cap,
                                  mask[u] > 0)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, ref)


def test_export_greedy_roundtrip():
    """export -> greedy returns exactly the frozen resident set."""
    _, cacher = _agents(_cfg("arc"))
    key = jax.random.PRNGKey(0)
    state = cache_state_init(_ENV.M)
    state = {**state,
             "in_t1": jnp.asarray([1, 0, 1, 0, 0, 0, 0, 0], jnp.bool_),
             "in_t2": jnp.asarray([0, 0, 0, 0, 1, 0, 0, 0], jnp.bool_)}
    pol = cacher.export(state)
    rho = cacher.greedy(pol, None, key)
    np.testing.assert_array_equal(np.asarray(rho),
                                  np.asarray(cache_rho(state)))


@pytest.mark.parametrize("kind", CACHE_POLICIES)
def test_train_single_env(kind):
    """B=1 driver run: state machine evolves, zero storage violations
    (the quantization guarantee), finite stats."""
    ts, hist = train_t2drl(_cfg(kind), episodes=2)
    assert float(jnp.max(hist["storage_viol"])) == 0.0
    assert bool(jnp.any(ts["cache"]["in_t1"] | ts["cache"]["in_t2"]))
    assert int(ts["cache"]["time"]) == 2 * _ENV.T * _ENV.K * _ENV.U
    for v in hist.values():
        assert bool(jnp.all(jnp.isfinite(v)))
    pol = export_policy(ts, _cfg(kind))
    assert set(pol) == {"cache"}
    ev = eval_t2drl(ts, _cfg(kind), episodes=1)
    assert 0.0 <= float(ev["hit_ratio"]) <= 1.0


def test_fused_vs_vmap_bit_identical():
    """B=4 independent cells: the fused episode program and the legacy
    vmap program agree — cache state machines (all-integer) bit-for-bit,
    float stat aggregates to XLA codegen round-off only (the §13 episode
    round-off contract; the underlying decisions are discrete)."""
    out = {}
    for impl in ("fused", "vmap"):
        cfg = _cfg("arc", independent_impl=impl)
        ts, hist = train_t2drl(cfg, episodes=2, num_envs=4)
        out[impl] = (ts["cache"], hist)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), out["fused"][0], out["vmap"][0])
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6),
        out["fused"][1], out["vmap"][1])
    # the discrete decision trace is exact: identical per-cell hit COUNTS
    n_req = _ENV.T * _ENV.K * _ENV.U
    np.testing.assert_array_equal(
        np.round(np.asarray(out["fused"][1]["hit_ratio"]) * n_req),
        np.round(np.asarray(out["vmap"][1]["hit_ratio"]) * n_req))


def test_shared_mode_cache_is_per_cell():
    """Shared-learner mode still gives every cell its own cache state
    (cache rides _ENV_AXIS_KEYS, not the shared-agent squeeze)."""
    cfg = _cfg("arc", policy="shared")
    ts, hist = train_t2drl(cfg, episodes=2, num_envs=2)
    assert ts["cache"]["in_t1"].shape == (2, _ENV.M)
    assert float(jnp.max(hist["storage_viol"])) == 0.0
    # heterogeneous zoos + independent streams -> cells may diverge; at
    # minimum both evolved
    assert bool(jnp.all(ts["cache"]["time"] > 0))


def test_masked_users_reduce_accesses():
    """Driver-level mask handling: inactive users are replayed as no-op
    accesses — the cache clock counts exactly the valid requests."""
    cfg = _cfg("lru")
    ts_full, _ = train_t2drl(cfg, episodes=1)
    assert int(ts_full["cache"]["time"]) == _ENV.T * _ENV.K * _ENV.U
    ts_masked, _ = train_t2drl(cfg, episodes=1, num_envs=1, user_counts=[4])
    assert int(ts_masked["cache"]["time"]) == _ENV.T * _ENV.K * 4
