"""System/integration tests: end-to-end training (loss decreases), serving
engine continuous batching, edge gateway, checkpoint roundtrip, data
pipeline, roofline parser, sharding fit."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.data import make_lm_batch
from repro.launch.roofline import (active_fraction, collective_bytes,
                                   model_flops, roofline)

KEY = jax.random.PRNGKey(0)


def test_training_reduces_loss_end_to_end():
    from repro.launch.train import train_loop
    _, hist = train_loop("qwen2-0.5b", smoke=True, steps=60, batch=8,
                         seq_len=64, lr=3e-3, log_every=0)
    first = np.mean(hist[:5])
    last = np.mean(hist[-5:])
    assert last < first - 0.5, (first, last)


def test_synthetic_stream_is_learnable_structure():
    b = make_lm_batch(KEY, vocab=97, batch=4, seq_len=64, structure=1.0)
    toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
    # with structure=1.0 labels follow the affine successor rule exactly
    np.testing.assert_array_equal(labels, (31 * toks + 17) % 97)
    # and tokens are the shifted labels
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])


def test_serving_engine_continuous_batching():
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.serving import Engine, ServeCfg
    cfg = get_arch("qwen2-0.5b").make_smoke()
    params = lm_mod.lm_init(KEY, cfg)
    eng = Engine(cfg, params, ServeCfg(max_batch=2, max_seq=64))
    reqs = [(i, np.arange(3 + i, dtype=np.int32) % cfg.vocab, 5)
            for i in range(4)]
    done, stats = eng.run(reqs)
    assert set(done) == {0, 1, 2, 3}
    assert all(len(v) == 6 for v in done.values())  # prefill tok + 5 decode
    # continuous batching must beat 1-at-a-time: 4 requests, 2 slots
    assert stats["decode_steps"] <= 4 * 5


def test_engine_decode_matches_offline_forward():
    """Greedy generation through the engine equals argmax over lm_forward."""
    from repro.configs import get_arch
    from repro.models import lm as lm_mod
    from repro.serving import Engine, ServeCfg
    cfg = get_arch("olmo-1b").make_smoke()
    params = lm_mod.lm_init(KEY, cfg)
    eng = Engine(cfg, params, ServeCfg(max_batch=1, max_seq=64))
    prompt = np.arange(8, dtype=np.int32)
    done, _ = eng.run([(0, prompt, 4)])
    gen = done[0]
    ctx = list(prompt)
    for tok in gen:
        logits, _ = lm_mod.lm_forward(
            params, cfg, jnp.asarray([ctx], jnp.int32))
        expect = int(jnp.argmax(logits[0, -1]))
        assert tok == expect
        ctx.append(tok)


def test_edge_gateway_caching_and_execution():
    from repro.serving import CatalogEntry, EdgeGateway
    from repro.serving.gateway import toy_diffusion_builder
    cat = [CatalogEntry(model_id=i, name=f"m{i}", kind="diffusion",
                        size_gb=4.0 + i, builder=toy_diffusion_builder(i, 32))
           for i in range(3)]
    gw = EdgeGateway(cat, capacity_gb=10.0, image_dim=32, total_steps=50)
    info = gw.apply_caching(np.array([1.0, 1.0, 1.0]))
    assert info["used_gb"] <= 10.0       # 4 + 5 fit; 6 does not
    assert info["n_loaded"] == 2
    res = gw.serve_slot([0, 2], np.array([0.5, 0.5]), KEY)
    assert res[0].cached and not res[1].cached
    assert res[0].measured_wall_s > 0.0
    assert res[1].modeled_quality == cat[2].a4
    # eviction
    gw.apply_caching(np.array([0.0, 0.0, 1.0]))
    assert 0 not in gw.loaded and 2 in gw.loaded


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import load_pytree, save_pytree
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": [jnp.ones(4, jnp.int32), {"c": jnp.float32(2.5)}],
            "d": jnp.zeros(3, jnp.bfloat16)}
    path = str(tmp_path / "ck.msgpack")
    save_pytree(path, tree)
    back = load_pytree(path)
    assert back["a"].dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"][0]),
                                  np.asarray(tree["b"][0]))
    assert float(back["b"][1]["c"]) == 2.5
    assert back["d"].dtype == jnp.bfloat16


def test_fit_spec_drops_nondividing_axes():
    from repro.nn.sharding import fit_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    assert fit_spec(P("model", None), (50280, 768), fm) == P(None, None)
    assert fit_spec(P("model", None), (51200, 768), fm) == P("model", None)
    assert fit_spec(P(("data", "model"), None), (512, 8), fm) == \
        P(("data", "model"), None)
    assert fit_spec(P(("data", "model"), None), (32, 8), fm) == P("data", None)
    assert fit_spec(P("data",), (1, 1), fm) == P(None)
    del mesh


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(bf16[16,64]{1,0} %x), dims={1}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%sum
  %rs = f32[8,32]{1,0} reduce-scatter(f32[8,512]{1,0} %z), dims={1}
  %cp = bf16[4,4]{1,0} collective-permute(bf16[4,4]{1,0} %w)
  %mm = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
"""
    cb = collective_bytes(hlo)
    assert cb["all-gather"] == 16 * 1024 * 2
    assert cb["all-reduce"] == 256 * 4
    assert cb["reduce-scatter"] == 8 * 32 * 4
    assert cb["collective-permute"] == 4 * 4 * 2
    assert cb["total"] == (16 * 1024 * 2 + 2 * 256 * 4 + 8 * 32 * 4
                           + 4 * 4 * 2)


def test_roofline_terms_and_bottleneck():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    r = roofline(cost, {"total": 50e9}, chips=256,
                 model_flops_total=197e12 * 256 * 0.5)
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 2.0) < 1e-6
    assert abs(r.collective_s - 1.0) < 1e-6
    assert r.bottleneck == "memory"
    assert abs(r.useful_ratio - 0.5) < 1e-6


def test_active_fraction_moe_vs_dense():
    from repro.configs import get_arch
    dense = get_arch("qwen2-0.5b").make_full()
    moe = get_arch("deepseek-v3-671b").make_full()
    assert active_fraction(dense) == 1.0
    f = active_fraction(moe)
    assert 0.02 < f < 0.3  # 37B active / 671B total ≈ 0.055


def test_model_flops_formula():
    assert model_flops(1e9, 1e6, "train") == 6e15
    assert model_flops(1e9, 1e6, "infer") == 2e15


@pytest.mark.slow
def test_dryrun_subprocess_single_pair():
    """The dry-run must lower+compile a real pair with 512 host devices in a
    fresh process (the XLA_FLAGS isolation contract)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.abspath(os.path.join(
                   os.path.dirname(__file__), "..", "src")))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "olmo-1b",
         "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=540)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "All dry-runs lowered + compiled successfully" in out.stdout
