"""Tests for the RL stack: diffusion schedule/sampler, D3PG updates, DDQN
amender/updates, replay buffers, GA baseline, and a short T2DRL episode."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (D3PGCfg, DDQNCfg, EnvCfg, GACfg, T2DRLCfg,
                        actor_act, amend_caching, critic_q, d3pg_init,
                        d3pg_update, ddqn_act, ddqn_init, ddqn_update,
                        env_reset, ga_allocate, make_actor_schedule,
                        make_models, run_episode, t2drl_init)
from repro.core.baselines import random_cache, static_popular_cache
from repro.core.buffers import buffer_add, buffer_init, buffer_sample
from repro.diffusion import make_schedule, reverse_sample_actions, denoiser_init

KEY = jax.random.PRNGKey(0)


# -- diffusion schedule / sampler ----------------------------------------------

def test_paper_beta_schedule_formula():
    L, bmin, bmax = 10, 0.1, 10.0
    sched = make_schedule(L, beta_min=bmin, beta_max=bmax, kind="paper")
    l = np.arange(1, L + 1)
    expect = 1 - np.exp(-bmin / L - (2 * l - 1) / (2 * L**2) * (bmax - bmin))
    np.testing.assert_allclose(np.asarray(sched.betas), expect, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sched.alpha_bars),
                               np.cumprod(1 - expect), rtol=1e-5)


def test_reverse_sampler_shapes_and_range():
    cfg = D3PGCfg(state_dim=12, action_dim=6, L=5)
    sched = make_actor_schedule(cfg)
    p = denoiser_init(KEY, 12, 6)
    s = jax.random.normal(KEY, (4, 12))
    a = reverse_sample_actions(p, sched, s, KEY, 6)
    assert a.shape == (4, 6)
    assert float(jnp.min(a)) >= 0.0 and float(jnp.max(a)) <= 1.0


def test_reverse_sampler_is_differentiable():
    cfg = D3PGCfg(state_dim=8, action_dim=4, L=3)
    sched = make_actor_schedule(cfg)
    p = denoiser_init(KEY, 8, 4)
    s = jax.random.normal(KEY, (8,))

    def f(p):
        return jnp.sum(reverse_sample_actions(p, sched, s, KEY, 4))

    g = jax.grad(f)(p)
    gnorm = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0.0


def test_pallas_sampler_matches_xla_sampler():
    cfg = D3PGCfg(state_dim=8, action_dim=4, L=4)
    sched = make_actor_schedule(cfg)
    p = denoiser_init(KEY, 8, 4)
    s = jax.random.normal(KEY, (3, 8))
    a1 = reverse_sample_actions(p, sched, s, KEY, 4, impl="xla")
    a2 = reverse_sample_actions(p, sched, s, KEY, 4, impl="pallas")
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2),
                               rtol=1e-4, atol=1e-5)


# -- buffers -------------------------------------------------------------------

def test_buffer_cyclic_overwrite_and_sample():
    buf = buffer_init(4, {"x": jnp.zeros(2), "y": jnp.int32(0)})
    for i in range(6):
        buf = buffer_add(buf, {"x": jnp.full(2, float(i)),
                               "y": jnp.int32(i)})
    assert int(buf["size"]) == 4
    assert int(buf["ptr"]) == 2
    # oldest entries (0, 1) were overwritten by (4, 5)
    ys = set(np.asarray(buf["data"]["y"]).tolist())
    assert ys == {2, 3, 4, 5}
    batch = buffer_sample(buf, KEY, 16)
    assert batch["x"].shape == (16, 2)
    assert set(np.asarray(batch["y"]).tolist()) <= ys


# -- DDQN ---------------------------------------------------------------------

@given(st.integers(0, 2**10 - 1))
@settings(max_examples=40, deadline=None)
def test_caching_amender_binary_decode(a_int):
    cfg = DDQNCfg(M=10)
    rho = amend_caching(jnp.int32(a_int), cfg)
    bits = [(a_int >> (10 - m)) % 2 for m in range(1, 11)]
    np.testing.assert_array_equal(np.asarray(rho), np.array(bits, np.float32))


def test_feasible_amender_respects_capacity():
    cfg = DDQNCfg(M=6, feasible_amender=True)
    c = jnp.array([4.0, 3.0, 5.0, 2.0, 6.0, 1.0])
    rho = amend_caching(jnp.int32(2**6 - 1), cfg, c, C=8.0)  # all requested
    assert float(jnp.sum(rho * c)) <= 8.0


def test_ddqn_update_reduces_td_error():
    cfg = DDQNCfg(M=4, J=3, lr=1e-2)
    params = ddqn_init(KEY, cfg)
    batch = {"s": jnp.zeros(32, jnp.int32), "a": jnp.ones(32, jnp.int32),
             "r": jnp.full(32, 5.0), "s1": jnp.ones(32, jnp.int32)}
    _, loss0 = ddqn_update(params, cfg, batch)
    p = params
    for _ in range(50):
        p, loss = ddqn_update(p, cfg, batch)
    assert float(loss) < float(loss0)


def test_ddqn_act_greedy_vs_random():
    cfg = DDQNCfg(M=4, J=3)
    params = ddqn_init(KEY, cfg)
    a_greedy = ddqn_act(params, cfg, jnp.int32(0), KEY, jnp.float32(0.0))
    a_greedy2 = ddqn_act(params, cfg, jnp.int32(0),
                         jax.random.fold_in(KEY, 7), jnp.float32(0.0))
    assert int(a_greedy) == int(a_greedy2)  # greedy is key-independent
    draws = {int(ddqn_act(params, cfg, jnp.int32(0),
                          jax.random.fold_in(KEY, i), jnp.float32(1.0)))
             for i in range(20)}
    assert len(draws) > 3  # eps=1 explores


# -- D3PG ---------------------------------------------------------------------

def _d3pg_batch(cfg, env_cfg, n=16):
    ks = jax.random.split(KEY, 6)
    U, M = env_cfg.U, env_cfg.M
    return {
        "s": jax.random.normal(ks[0], (n, cfg.state_dim)),
        "a": jax.random.uniform(ks[1], (n, cfg.action_dim)),
        "r": jax.random.normal(ks[2], (n,)),
        "s1": jax.random.normal(ks[3], (n, cfg.state_dim)),
        "req": jax.random.randint(ks[4], (n, U), 0, M),
        "rho": jnp.ones((n, M)),
        "req1": jax.random.randint(ks[5], (n, U), 0, M),
        "rho1": jnp.ones((n, M)),
    }


def test_d3pg_update_moves_both_networks():
    env_cfg = EnvCfg(U=4, M=4)
    cfg = D3PGCfg(state_dim=env_cfg.state_dim, action_dim=env_cfg.action_dim,
                  L=3, lr_actor=1e-3, lr_critic=1e-3)
    params = d3pg_init(KEY, cfg)
    sched = make_actor_schedule(cfg)
    batch = _d3pg_batch(cfg, env_cfg)
    new, losses = d3pg_update(params, cfg, sched, batch, KEY)
    for name in ("actor", "critic"):
        delta = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(params[name]), jax.tree.leaves(new[name])))
        assert delta > 0.0, name
        # target networks move slowly (Polyak 0.005)
        tdelta = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree.leaves(params[name + "_t"]),
            jax.tree.leaves(new[name + "_t"])))
        assert 0.0 < tdelta < delta
    assert np.isfinite(float(losses["critic_loss"]))


def test_ddpg_mlp_actor_variant():
    env_cfg = EnvCfg(U=4, M=4)
    cfg = D3PGCfg(state_dim=env_cfg.state_dim, action_dim=env_cfg.action_dim,
                  actor_kind="mlp")
    params = d3pg_init(KEY, cfg)
    sched = make_actor_schedule(cfg)
    s = jax.random.normal(KEY, (env_cfg.state_dim,))
    a = actor_act(params["actor"], cfg, sched, s, KEY)
    assert a.shape == (env_cfg.action_dim,)
    assert float(jnp.min(a)) >= 0.0 and float(jnp.max(a)) <= 1.0


# -- baselines -----------------------------------------------------------------

def test_static_and_random_cache_respect_capacity():
    env_cfg = EnvCfg(U=4, M=8, C=15.0)
    models = make_models(KEY, env_cfg)
    rho_s = static_popular_cache(models, env_cfg)
    assert float(jnp.sum(rho_s * models.c)) <= env_cfg.C
    for i in range(5):
        rho_r = random_cache(jax.random.fold_in(KEY, i), models, env_cfg)
        assert float(jnp.sum(rho_r * models.c)) <= env_cfg.C


def test_ga_allocation_satisfies_constraints_and_beats_random():
    env_cfg = EnvCfg(U=5, M=5)
    models = make_models(KEY, env_cfg)
    state = env_reset(KEY, env_cfg)
    state = state._replace(rho=jnp.ones(env_cfg.M))
    ga = GACfg(pop=16, gens=10)
    b, xi = ga_allocate(KEY, state, env_cfg, models, ga)
    assert abs(float(jnp.sum(b)) - 1.0) < 1e-4
    assert abs(float(jnp.sum(xi)) - 1.0) < 1e-4
    from repro.core import slot_metrics

    def ga_objective(b_, xi_):
        # what the GA minimises: the slot objective (12) + deadline penalty
        m = slot_metrics(state, env_cfg, models, b_, xi_)
        viol = (m["d_tl"] > env_cfg.tau).astype(jnp.float32)
        return float(jnp.mean(m["G"] + viol * env_cfg.chi))

    # warm start + elitism: GA never does worse than the amended
    # equal-split chromosome it was seeded with
    from repro.core import amend_actions
    b_ws, xi_ws = amend_actions(jnp.full((2 * env_cfg.U,), 0.5), state.req,
                                state.rho, env_cfg.U)
    assert ga_objective(b, xi) <= ga_objective(b_ws, xi_ws) + 1e-3


# -- T2DRL integration -----------------------------------------------------------

def test_t2drl_episode_runs_and_buffers_fill():
    cfg = T2DRLCfg(env=EnvCfg(U=4, M=4, T=3, K=3), warmup=5,
                   lr_actor=1e-4, lr_critic=1e-4, lr_ddqn=1e-3, L=2)
    ts = t2drl_init(KEY, cfg)
    ts, stats = run_episode(ts, cfg, KEY, jnp.float32(0.5),
                            jnp.float32(0.1), train=True)
    assert int(ts["ebuf"]["size"]) == 9      # T*K slot transitions
    assert int(ts["fbuf"]["size"]) == 2      # T-1 frame transitions
    for k in ("episode_reward", "hit_ratio", "utility"):
        assert np.isfinite(float(stats[k])), k
    assert 0.0 <= float(stats["hit_ratio"]) <= 1.0


def test_t2drl_eval_deterministic_given_key():
    cfg = T2DRLCfg(env=EnvCfg(U=4, M=4, T=2, K=2), L=2)
    ts = t2drl_init(KEY, cfg)
    _, s1 = run_episode(ts, cfg, KEY, jnp.float32(0.0), jnp.float32(0.0),
                        train=False)
    _, s2 = run_episode(ts, cfg, KEY, jnp.float32(0.0), jnp.float32(0.0),
                        train=False)
    assert float(s1["episode_reward"]) == float(s2["episode_reward"])
