"""Agent protocol (DESIGN.md §12): bit-identity pins of every refactored
agent's init/act/update against the legacy numeric cores, the generic
vmap_agent batching wrapper, the per-frame batched replay writes, the
replay-sampling contract, and the new schedule / updates_per_slot levers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents import (FrameObs, SlotObs, d3pg_allocator, ddqn_cacher,
                          make_allocator, make_cacher, rcars_allocator,
                          schrs_allocator, vmap_agent)
from repro.core import (EnvCfg, T2DRLCfg, actor_act, amend_actions,
                        amend_caching, d3pg_init, d3pg_init_batch,
                        d3pg_update, d3pg_update_batch, ddqn_act, ddqn_init,
                        ddqn_update, env_reset, episode_epsilon,
                        episode_lr_scale, episode_sigma, make_actor_schedule,
                        make_models, train_t2drl)
from repro.core.baselines import (ga_allocate, random_cache, rcars_allocate,
                                  static_popular_cache)
from repro.core.buffers import (buffer_add, buffer_add_many, buffer_init,
                                buffer_sample)

KEY = jax.random.PRNGKey(0)
ENV = EnvCfg(U=4, M=4, T=3, K=3)
CFG = T2DRLCfg(env=ENV, warmup=5, lr_actor=1e-4, lr_critic=1e-4,
               lr_ddqn=1e-3, L=2, eps_decay_episodes=4, seed=0)

D3 = CFG.d3pg_cfg()
DQ = CFG.ddqn_cfg()
STEP = {"eps": jnp.float32(0.3), "sigma": jnp.float32(0.1)}


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _slot_batch(n=16):
    ks = jax.random.split(KEY, 6)
    return {
        "s": jax.random.normal(ks[0], (n, D3.state_dim)),
        "a": jax.random.uniform(ks[1], (n, D3.action_dim)),
        "r": jax.random.normal(ks[2], (n,)),
        "s1": jax.random.normal(ks[3], (n, D3.state_dim)),
        "req": jax.random.randint(ks[4], (n, ENV.U), 0, ENV.M),
        "rho": jnp.ones((n, ENV.M)),
        "req1": jax.random.randint(ks[5], (n, ENV.U), 0, ENV.M),
        "rho1": jnp.ones((n, ENV.M)),
    }


# -- d3pg agent == legacy d3pg_* ----------------------------------------------

def test_d3pg_agent_init_bit_identical():
    _tree_equal(d3pg_allocator(D3).init(KEY), d3pg_init(KEY, D3))


def test_d3pg_agent_act_composes_actor_noise_amender():
    alloc = d3pg_allocator(D3)
    state = alloc.init(KEY)
    models = make_models(KEY, ENV)
    env = env_reset(jax.random.PRNGKey(3), ENV)._replace(rho=jnp.ones(ENV.M))
    s = jax.random.normal(KEY, (D3.state_dim,))
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    b, xi = alloc.act(state, SlotObs(s, env, models, None), ks[:2], STEP)
    sched = make_actor_schedule(D3)
    raw = actor_act(state["actor"], D3, sched, s, ks[0])
    raw = jnp.clip(raw + STEP["sigma"] * jax.random.normal(ks[1], raw.shape),
                   0.0, 1.0)
    b_ref, xi_ref = amend_actions(raw, env.req, env.rho, ENV.U)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))


def test_d3pg_agent_update_bit_identical():
    alloc = d3pg_allocator(D3)
    params = alloc.init(KEY)
    batch = _slot_batch()
    new_a, metrics_a = alloc.update(params, batch, KEY)
    sched = make_actor_schedule(D3)
    new_l, metrics_l = d3pg_update(params, D3, sched, batch, KEY)
    _tree_equal(new_a, new_l)
    _tree_equal(metrics_a, metrics_l)


def test_d3pg_agent_update_reserved_aux_keys():
    """mask / lr_* ride in the batch dict and must reproduce the legacy
    keyword arguments exactly (and not leak into the minibatch)."""
    alloc = d3pg_allocator(D3)
    params = alloc.init(KEY)
    batch = _slot_batch()
    mask = jnp.array([1.0, 1.0, 0.0, 0.0])
    lr = jnp.float32(3e-4)
    new_a, _ = alloc.update(
        params, {**batch, "mask": mask, "lr_actor": lr, "lr_critic": lr},
        KEY)
    sched = make_actor_schedule(D3)
    new_l, _ = d3pg_update(params, D3, sched, batch, KEY, mask=mask,
                           lr_a=lr, lr_c=lr)
    _tree_equal(new_a, new_l)


# -- ddqn agent == legacy ddqn_* ----------------------------------------------

def test_ddqn_agent_init_act_update_bit_identical():
    cacher = ddqn_cacher(DQ, ENV)
    _tree_equal(cacher.init(KEY), ddqn_init(KEY, DQ))
    state = cacher.init(KEY)
    models = make_models(KEY, ENV)
    gamma = jnp.int32(1)
    a_int, rho = cacher.act(state, FrameObs(gamma, models), KEY, STEP)
    a_ref = ddqn_act(state, DQ, gamma, KEY, STEP["eps"])
    assert int(a_int) == int(a_ref)
    np.testing.assert_array_equal(
        np.asarray(rho), np.asarray(amend_caching(a_ref, DQ, models.c,
                                                  ENV.C)))
    batch = {"s": jnp.zeros(8, jnp.int32), "a": jnp.ones(8, jnp.int32),
             "r": jnp.full(8, 2.0), "s1": jnp.ones(8, jnp.int32)}
    new_a, metrics = cacher.update(state, batch, KEY)
    new_l, loss = ddqn_update(state, DQ, batch)
    _tree_equal(new_a, new_l)
    assert float(metrics["loss"]) == float(loss)


# -- baseline agents == legacy baseline fns -----------------------------------

def test_baseline_agents_match_legacy_functions():
    models = make_models(KEY, ENV)
    env = env_reset(jax.random.PRNGKey(3), ENV)._replace(
        rho=static_popular_cache(models, ENV))
    obs = SlotObs(None, env, models, None)
    b, xi = rcars_allocator(ENV).act({}, obs, jax.random.split(KEY, 2), STEP)
    b_ref, xi_ref = rcars_allocate(env, ENV)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    b, xi = schrs_allocator(ENV, CFG.ga).act({}, obs, ks, STEP)
    b_ref, xi_ref = ga_allocate(ks[0], env, ENV, models, CFG.ga)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))
    _, rho = make_cacher("static", DQ, ENV).act({}, FrameObs(env.gamma_idx,
                                                             models), KEY,
                                                STEP)
    np.testing.assert_array_equal(
        np.asarray(rho), np.asarray(static_popular_cache(models, ENV)))
    _, rho = make_cacher("random", DQ, ENV).act({}, FrameObs(env.gamma_idx,
                                                             models), KEY,
                                                STEP)
    np.testing.assert_array_equal(
        np.asarray(rho), np.asarray(random_cache(KEY, models, ENV)))


def test_make_allocator_rejects_unknown_names():
    with pytest.raises(ValueError, match="unknown allocator"):
        make_allocator("nope", ENV, D3, CFG.ga)
    with pytest.raises(ValueError, match="unknown cacher"):
        make_cacher("nope", DQ, ENV)


# -- vmap_agent and the compat shims ------------------------------------------

def _stacked_batches(B, n=8):
    """B structurally-identical minibatches with per-cell float variation
    (integer leaves — request ids — keep their dtype)."""
    def cell(i):
        return jax.tree.map(
            lambda x: x if jnp.issubdtype(x.dtype, jnp.integer)
            else x * (0.5 + 0.5 * i), _slot_batch(n))
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[cell(i)
                                                     for i in range(B)])


def test_vmap_agent_equals_per_cell_calls():
    B = 3
    keys = jax.random.split(KEY, B)
    batched = vmap_agent(d3pg_allocator(D3))
    params_b = batched.init(keys)
    for i in range(B):
        _tree_equal(jax.tree.map(lambda x: x[i], params_b),
                    d3pg_init(keys[i], D3))
    batch_b = _stacked_batches(B)
    upd_keys = jax.random.split(jax.random.PRNGKey(2), B)
    new_b, _ = batched.update(params_b, batch_b, upd_keys)
    sched = make_actor_schedule(D3)
    for i in range(B):
        ref, _ = d3pg_update(jax.tree.map(lambda x: x[i], params_b), D3,
                             sched, jax.tree.map(lambda x: x[i], batch_b),
                             upd_keys[i])
        _tree_equal(jax.tree.map(lambda x: x[i], new_b), ref)


def test_compat_batch_shims_route_through_protocol():
    B = 2
    keys = jax.random.split(KEY, B)
    params_b = d3pg_init_batch(keys, D3)
    _tree_equal(params_b, vmap_agent(d3pg_allocator(D3)).init(keys))
    batch_b = _stacked_batches(B)
    sched = make_actor_schedule(D3)
    new_b, losses = d3pg_update_batch(params_b, D3, sched, batch_b, keys)
    assert losses["critic_loss"].shape == (B,)
    ref, _ = d3pg_update(jax.tree.map(lambda x: x[0], params_b), D3, sched,
                         jax.tree.map(lambda x: x[0], batch_b), keys[0])
    _tree_equal(jax.tree.map(lambda x: x[0], new_b), ref)


# -- replay buffers: batched writes + sampling contract (DESIGN.md §12) -------

def test_buffer_add_many_equals_sequential_adds_with_wraparound():
    item = lambda i: {"x": jnp.full((2,), float(i)), "y": jnp.int32(i)}
    many = lambda lo, hi: {"x": jnp.stack([jnp.full((2,), float(i))
                                          for i in range(lo, hi)]),
                           "y": jnp.arange(lo, hi, dtype=jnp.int32)}
    a = buffer_init(5, item(0))
    b = buffer_init(5, item(0))
    for i in range(3):
        a = buffer_add(a, item(i))
    b = buffer_add_many(b, many(0, 3))
    _tree_equal(a, b)
    # wrap: 4 more items into capacity 5 (ptr wraps past the end)
    for i in range(3, 7):
        a = buffer_add(a, item(i))
    b = buffer_add_many(b, many(3, 7))
    _tree_equal(a, b)
    assert int(b["ptr"]) == 2 and int(b["size"]) == 5
    # n > capacity would scatter duplicate indices (order-dependent):
    # refused loudly instead of silently losing determinism
    with pytest.raises(ValueError, match="capacity"):
        buffer_add_many(buffer_init(3, item(0)), many(0, 4))


def test_buffer_sample_contract():
    """The with-replacement draw is the documented contract (DESIGN.md
    §12): in-range indices, stored items only, deterministic per key."""
    buf = buffer_init(8, {"y": jnp.int32(0)})
    for i in range(5):
        buf = buffer_add(buf, {"y": jnp.int32(10 + i)})
    s1 = buffer_sample(buf, KEY, 16)
    s2 = buffer_sample(buf, KEY, 16)
    np.testing.assert_array_equal(np.asarray(s1["y"]), np.asarray(s2["y"]))
    assert set(np.asarray(s1["y"]).tolist()) <= {10, 11, 12, 13, 14}
    # never samples the empty tail of a partially-filled buffer
    assert 0 not in np.asarray(s1["y"]).tolist()
    # empty buffer degrades to row 0 rather than out-of-bounds
    empty = buffer_init(4, {"y": jnp.int32(0)})
    assert set(np.asarray(buffer_sample(empty, KEY, 4)["y"]).tolist()) == {0}


# -- schedules + updates_per_slot ---------------------------------------------

def test_epsilon_schedules_share_endpoints():
    lin = CFG
    cos = dataclasses.replace(CFG, eps_schedule="cosine")
    for cfg in (lin, cos):
        assert float(episode_epsilon(cfg, jnp.float32(0.0))) == cfg.eps_start
        np.testing.assert_allclose(
            float(episode_epsilon(cfg, jnp.float32(cfg.eps_decay_episodes))),
            cfg.eps_end, rtol=1e-6)
    # cosine holds exploration longer early on
    mid = jnp.float32(1.0)
    assert float(episode_epsilon(cos, mid)) > float(episode_epsilon(lin, mid))
    # sigma follows the same shape and is zero for non-learned allocators
    assert float(episode_sigma(cos, mid)) > float(episode_sigma(lin, mid))
    rc = dataclasses.replace(CFG, allocator="rcars")
    assert float(episode_sigma(rc, mid)) == 0.0
    # unknown names raise instead of silently falling back to linear
    with pytest.raises(ValueError, match="eps_schedule"):
        episode_epsilon(dataclasses.replace(CFG, eps_schedule="nope"), mid)


def test_lr_scale_schedule_endpoints_and_const_default():
    cfg = dataclasses.replace(CFG, lr_schedule="cosine",
                              lr_warmdown_episodes=10, lr_end_scale=0.25)
    assert float(episode_lr_scale(cfg, jnp.float32(0.0))) == 1.0
    np.testing.assert_allclose(
        float(episode_lr_scale(cfg, jnp.float32(10.0))), 0.25, rtol=1e-6)
    np.testing.assert_array_equal(
        np.asarray(episode_lr_scale(CFG, jnp.arange(4, dtype=jnp.float32))),
        np.ones(4, np.float32))
    with pytest.raises(ValueError, match="lr_schedule"):
        episode_lr_scale(dataclasses.replace(CFG, lr_schedule="nope"),
                         jnp.float32(1.0))
    # warmdown horizon of 0 would be an instant LR cliff, not a warmdown
    with pytest.raises(ValueError, match="lr_warmdown_episodes"):
        episode_lr_scale(dataclasses.replace(CFG, lr_schedule="cosine"),
                         jnp.float32(1.0))


def test_scheduled_training_runs_and_differs_from_default():
    sched_cfg = dataclasses.replace(
        CFG, eps_schedule="cosine", lr_schedule="cosine",
        lr_warmdown_episodes=3, lr_end_scale=0.2)
    _, h_sched = train_t2drl(sched_cfg, episodes=3, num_envs=1)
    _, h_base = train_t2drl(CFG, episodes=3, num_envs=1)
    r = np.asarray(h_sched["episode_reward"])
    assert r.shape == (3,) and np.all(np.isfinite(r))
    assert not np.array_equal(r, np.asarray(h_base["episode_reward"]))


@pytest.mark.parametrize("policy", ["independent", "shared"])
def test_updates_per_slot_trades_rollouts_for_gradient_steps(policy):
    base = dataclasses.replace(CFG, policy=policy)
    multi = dataclasses.replace(base, updates_per_slot=2)
    ts1, h1 = train_t2drl(base, episodes=2, num_envs=2)
    ts2, h2 = train_t2drl(multi, episodes=2, num_envs=2)
    assert np.all(np.isfinite(np.asarray(h2["episode_reward"])))
    # same rollouts (same PRNG stream), different learner trajectories
    np.testing.assert_array_equal(np.asarray(ts1["ebuf"]["size"]),
                                  np.asarray(ts2["ebuf"]["size"]))
    a1 = jax.tree.leaves(ts1["d3pg"])
    a2 = jax.tree.leaves(ts2["d3pg"])
    assert any(not np.array_equal(x, y) for x, y in zip(a1, a2))


def test_updates_per_slot_validation():
    bad = dataclasses.replace(CFG, updates_per_slot=0)
    with pytest.raises(ValueError, match="updates_per_slot"):
        train_t2drl(bad, episodes=1, num_envs=1)
