"""Fused B-learner path (DESIGN.md §13): bit-identity pins of every stacked
primitive and agent closure against the ``jax.vmap`` reference, the episode
-level equivalence contract, population-schedule semantics, and the
``shard_map`` multi-device placement (subprocess, forced host device count).

Equivalence contract (measured, see ``_episode_core_fused``): the fused and
vmapped programs compute the same math on the same PRNG streams, and every
pin below that says "bit-identical" is exact leaf for leaf.  Full EPISODES
are compared to float32 round-off instead: XLA CPU codegen is
context-dependent (FMA/fusion decisions differ per whole-program), so the
slot-reward accumulations of a rollout drift at the ULP level and chained
update arithmetic by ~1e-10 per update step — even though the minibatch
indices, update inputs, and any SINGLE update step are bitwise equal.
Discrete decisions (caching actions, hit ratios) stay exact; one training
episode lands within ~1e-5; real transposition bugs show up at ~1e-1.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.agents import SlotObs, d3pg_allocator, ddqn_cacher, vmap_agent
from repro.core import (EnvCfg, T2DRLCfg, env_reset_batch, run_eval,
                        run_training, t2drl_init_batch)
from repro.core.buffers import (buffer_add_many_batch, buffer_add_many_stacked,
                                buffer_init_batch, buffer_sample_batch,
                                buffer_sample_stacked)
from repro.core.d3pg import make_actor_schedule
from repro.core.ddqn import ddqn_act, ddqn_act_stacked
from repro.core.networks import (mlp_apply, mlp_apply_stacked, mlp_init,
                                 mlp_init_stacked)
from repro.core.t2drl import _validate_pop
from repro.diffusion import (denoiser_apply, denoiser_apply_stacked,
                             denoiser_init, reverse_sample,
                             reverse_sample_stacked)
from repro.optim import (adam_init, adam_update, adam_update_stacked,
                         global_norm, global_norm_stacked)

KEY = jax.random.PRNGKey(0)
ENV = EnvCfg(U=4, M=4, T=3, K=3)
CFG = T2DRLCfg(env=ENV, policy="independent", warmup=5, lr_actor=1e-4,
               lr_critic=1e-4, lr_ddqn=1e-3, L=2, eps_decay_episodes=4,
               seed=0)
CFG_FUSED = dataclasses.replace(CFG, independent_impl="fused")
CFG_VMAP = dataclasses.replace(CFG, independent_impl="vmap")
D3 = CFG.d3pg_cfg()
DQ = CFG.ddqn_cfg()


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _tree_close(a, b, *, atol=1e-4, rtol=1e-4):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


def _stacked_keys(key, B):
    return jax.random.split(key, B)


# -- stacked primitives == vmapped reference ----------------------------------

@pytest.mark.parametrize("B", [1, 4])
def test_mlp_apply_stacked_bit_identical(B):
    dims = [6, 16, 3]
    params = mlp_init_stacked(_stacked_keys(KEY, B), dims)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 5, 6))
    fused = jax.jit(mlp_apply_stacked)(params, x)
    ref = jax.jit(jax.vmap(mlp_apply))(params, x)
    _tree_equal(fused, ref)


@pytest.mark.parametrize("B", [1, 4])
def test_denoiser_apply_stacked_bit_identical(B):
    params = jax.vmap(
        lambda k: denoiser_init(k, 7, 4, hidden=16, n_layers=2))(
            _stacked_keys(KEY, B))
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 5, 4))
    s = jax.random.normal(jax.random.PRNGKey(2), (B, 5, 7))
    l = jnp.float32(2.0)
    fused = jax.jit(denoiser_apply_stacked)(params, x, l, s)
    ref = jax.jit(jax.vmap(denoiser_apply, in_axes=(0, 0, None, 0)))(
        params, x, l, s)
    _tree_equal(fused, ref)


@pytest.mark.parametrize("B", [1, 4])
def test_reverse_sample_stacked_bit_identical(B):
    sched = make_actor_schedule(D3)
    params = jax.vmap(
        lambda k: denoiser_init(k, D3.state_dim, D3.action_dim))(
            _stacked_keys(KEY, B))
    s = jax.random.normal(jax.random.PRNGKey(1), (B, 5, D3.state_dim))
    keys = _stacked_keys(jax.random.PRNGKey(2), B)
    fused = jax.jit(
        lambda p, s_, k: reverse_sample_stacked(p, sched, s_, k,
                                                D3.action_dim))(
        params, s, keys)
    ref = jax.jit(jax.vmap(
        lambda p, s_, k: reverse_sample(p, sched, s_, k, D3.action_dim)))(
        params, s, keys)
    _tree_equal(fused, ref)


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("per_learner_lr", [False, True])
def test_adam_update_stacked_bit_identical(B, per_learner_lr):
    params = mlp_init_stacked(_stacked_keys(KEY, B), [5, 8, 2])
    grads = jax.tree.map(
        lambda p: jax.random.normal(jax.random.PRNGKey(3), p.shape), params)
    state = jax.vmap(adam_init)(params)
    lr = (jnp.linspace(1e-4, 1e-3, B) if per_learner_lr else 1e-3)
    fused = jax.jit(
        lambda g, st, p: adam_update_stacked(g, st, p, lr=lr))(
        grads, state, params)
    lr_ax = 0 if per_learner_lr else None
    ref = jax.jit(jax.vmap(
        lambda g, st, p, l: adam_update(g, st, p, lr=l),
        in_axes=(0, 0, 0, lr_ax)))(
        grads, state, params, jnp.asarray(lr, jnp.float32))
    _tree_equal(fused, ref)


@pytest.mark.parametrize("B", [1, 4])
def test_global_norm_stacked_bit_identical(B):
    tree = mlp_init_stacked(_stacked_keys(KEY, B), [5, 8, 2])
    fused = jax.jit(global_norm_stacked)(tree)
    ref = jax.jit(jax.vmap(global_norm))(tree)
    _tree_equal(fused, ref)


@pytest.mark.parametrize("B", [1, 4])
def test_buffer_stacked_bit_identical(B):
    item = {"s": jnp.zeros(3), "r": jnp.float32(0.0)}
    buf = buffer_init_batch(B, 8, item)
    items = {"s": jax.random.normal(KEY, (B, 5, 3)),
             "r": jax.random.normal(jax.random.PRNGKey(1), (B, 5))}
    fused_buf = jax.jit(buffer_add_many_stacked)(buf, items)
    ref_buf = jax.jit(buffer_add_many_batch)(buf, items)
    _tree_equal(fused_buf, ref_buf)
    # second write wraps the ring cyclically in both paths
    fused_buf = jax.jit(buffer_add_many_stacked)(fused_buf, items)
    ref_buf = jax.jit(buffer_add_many_batch)(ref_buf, items)
    _tree_equal(fused_buf, ref_buf)
    keys = _stacked_keys(jax.random.PRNGKey(2), B)
    _tree_equal(jax.jit(lambda b, k: buffer_sample_stacked(b, k, 4))(
                    fused_buf, keys),
                jax.jit(lambda b, k: buffer_sample_batch(b, k, 4))(
                    ref_buf, keys))


# -- agent closures: vmap_agent(impl="fused") == vmap_agent(impl="vmap") ------

def _slot_batch_stacked(B, n=8):
    ks = jax.random.split(KEY, 6)
    return {
        "s": jax.random.normal(ks[0], (B, n, D3.state_dim)),
        "a": jax.random.uniform(ks[1], (B, n, D3.action_dim)),
        "r": jax.random.normal(ks[2], (B, n)),
        "s1": jax.random.normal(ks[3], (B, n, D3.state_dim)),
        "req": jax.random.randint(ks[4], (B, n, ENV.U), 0, ENV.M),
        "rho": jnp.ones((B, n, ENV.M)),
        "req1": jax.random.randint(ks[5], (B, n, ENV.U), 0, ENV.M),
        "rho1": jnp.ones((B, n, ENV.M)),
    }


def _frame_batch_stacked(B, n=8):
    ks = jax.random.split(KEY, 4)
    J, A = DQ.J, DQ.n_actions
    return {"s": jax.random.randint(ks[0], (B, n), 0, J),
            "a": jax.random.randint(ks[1], (B, n), 0, A),
            "r": jax.random.normal(ks[2], (B, n)),
            "s1": jax.random.randint(ks[3], (B, n), 0, J)}


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("kind", ["diffusion", "mlp"])
def test_d3pg_update_stacked_matches_vmap(B, kind):
    d3 = dataclasses.replace(D3, actor_kind=kind)
    agent = d3pg_allocator(d3)
    fused = vmap_agent(agent, impl="fused")
    ref = vmap_agent(agent, impl="vmap")
    state = fused.init(_stacked_keys(KEY, B))
    _tree_equal(state, ref.init(_stacked_keys(KEY, B)))
    batch = _slot_batch_stacked(B)
    keys = _stacked_keys(jax.random.PRNGKey(7), B)
    new_f, m_f = jax.jit(fused.update)(state, batch, keys)
    new_r, m_r = jax.jit(ref.update)(state, batch, keys)
    _tree_equal(new_f, new_r)
    _tree_equal(m_f, m_r)


@pytest.mark.parametrize("B", [1, 4])
def test_d3pg_update_stacked_per_learner_lr_matches_vmap(B):
    agent = d3pg_allocator(D3)
    fused = vmap_agent(agent, impl="fused")
    state = fused.init(_stacked_keys(KEY, B))
    batch = _slot_batch_stacked(B)
    batch["lr_actor"] = jnp.linspace(1e-5, 1e-4, B)
    batch["lr_critic"] = jnp.linspace(1e-4, 1e-3, B)
    keys = _stacked_keys(jax.random.PRNGKey(7), B)
    new_f, _ = jax.jit(fused.update)(state, batch, keys)
    # reference: vmap the per-learner update with per-learner scalar lr
    def one(st, bt, k, la, lc):
        bt = dict(bt, lr_actor=la, lr_critic=lc)
        return agent.update(st, bt, k)
    data = {k: v for k, v in batch.items()
            if k not in ("lr_actor", "lr_critic")}
    new_r, _ = jax.jit(jax.vmap(one))(state, data, keys,
                                      batch["lr_actor"], batch["lr_critic"])
    _tree_equal(new_f, new_r)


@pytest.mark.parametrize("B", [1, 4])
def test_ddqn_update_stacked_matches_vmap(B):
    agent = ddqn_cacher(DQ, ENV)
    fused = vmap_agent(agent, impl="fused")
    ref = vmap_agent(agent, impl="vmap")
    state = fused.init(_stacked_keys(KEY, B))
    batch = _frame_batch_stacked(B)
    keys = _stacked_keys(jax.random.PRNGKey(7), B)
    new_f, m_f = jax.jit(fused.update)(state, batch, keys)
    new_r, m_r = jax.jit(ref.update)(state, batch, keys)
    _tree_equal(new_f, new_r)
    _tree_equal(m_f, m_r)


@pytest.mark.parametrize("B", [1, 4])
def test_ddqn_act_stacked_matches_vmap(B):
    agent = ddqn_cacher(DQ, ENV)
    state = vmap_agent(agent, impl="fused").init(_stacked_keys(KEY, B))
    g_idx = jax.random.randint(jax.random.PRNGKey(1), (B,), 0, DQ.J)
    keys = _stacked_keys(jax.random.PRNGKey(2), B)
    # eps=0.5 exercises both the explore and exploit branches
    a_f = jax.jit(lambda s, g, k: ddqn_act_stacked(s, DQ, g, k, 0.5))(
        state, g_idx, keys)
    a_r = jax.jit(jax.vmap(
        lambda s, g, k: ddqn_act(s, DQ, g, k, 0.5)))(state, g_idx, keys)
    _tree_equal(a_f, a_r)


@pytest.mark.parametrize("B", [1, 4])
def test_d3pg_act_stacked_matches_vmap(B):
    agent = d3pg_allocator(D3)
    fused = vmap_agent(agent, impl="fused")
    ref = vmap_agent(agent, impl="vmap")
    state = fused.init(_stacked_keys(KEY, B))
    s = jax.random.normal(jax.random.PRNGKey(1), (B, D3.state_dim))
    env = env_reset_batch(_stacked_keys(jax.random.PRNGKey(2), B), ENV, None)
    obs = SlotObs(s=s, env=env, models=None, mask=None)
    keys = jnp.stack([_stacked_keys(jax.random.PRNGKey(3), B),
                      _stacked_keys(jax.random.PRNGKey(4), B)], axis=1)
    step = {"sigma": jnp.float32(0.1)}
    b_f, xi_f = jax.jit(fused.act)(state, obs, keys, step)
    b_r, xi_r = jax.jit(ref.act)(state, obs, keys, step)
    _tree_equal((b_f, xi_f), (b_r, xi_r))


def test_vmap_agent_rejects_unknown_impl():
    with pytest.raises(ValueError, match="unknown impl"):
        vmap_agent(d3pg_allocator(D3), impl="turbo")


# -- episode-level equivalence ------------------------------------------------

def test_rollout_episode_fused_vs_vmap_round_off():
    """train=False episodes (rollout + replay writes, no updates): every
    discrete quantity is exact; the per-episode reward accumulations agree
    to float32 round-off (ULP-level — the fused and vmapped programs are
    different whole-programs, so XLA CPU's fusion/FMA choices differ in the
    slot-reward summations).  A tighter tolerance than the training pin:
    there is no chained-update amplification here."""
    B = 4
    key = jax.random.PRNGKey(5)
    ts_f = t2drl_init_batch(KEY, CFG_FUSED, B)
    ts_v = t2drl_init_batch(KEY, CFG_VMAP, B)
    _tree_equal(ts_f, ts_v)
    ts_f, st_f = run_training(ts_f, CFG_FUSED, key, jnp.arange(2),
                              train=False)
    ts_v, st_v = run_training(ts_v, CFG_VMAP, key, jnp.arange(2),
                              train=False)
    _tree_close(st_f, st_v, atol=1e-4, rtol=1e-6)
    _tree_close(ts_f, ts_v, atol=1e-4, rtol=1e-6)
    # discrete stats are exact: identical action/caching decisions
    for k in ("hit_ratio", "deadline_viol", "storage_viol"):
        np.testing.assert_array_equal(np.asarray(st_f[k]),
                                      np.asarray(st_v[k]))


def test_eval_fused_vs_vmap_round_off():
    B = 4
    ts = t2drl_init_batch(KEY, CFG_FUSED, B)
    st_f = run_eval(ts, CFG_FUSED, jax.random.PRNGKey(5), jnp.arange(2))
    st_v = run_eval(ts, CFG_VMAP, jax.random.PRNGKey(5), jnp.arange(2))
    _tree_close(st_f, st_v, atol=1e-4, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(st_f["hit_ratio"]),
                                  np.asarray(st_v["hit_ratio"]))


def test_training_episode_fused_vs_vmap_tolerance():
    """One TRAINING episode agrees to float32 round-off (~1e-5 observed).

    Not a bit-exact pin on purpose: XLA CPU emits context-dependent code
    (FMA/fusion choices differ per whole-program), so even the vmap
    reference is not bit-stable against a replay of its own update chain.
    The minibatch indices, update inputs, and single update steps ARE
    bitwise equal (pinned above); real layout bugs produce ~1e-1 errors
    here, three orders of magnitude above this tolerance."""
    B = 4
    key = jax.random.PRNGKey(5)
    ts_f = t2drl_init_batch(KEY, CFG_FUSED, B)
    ts_v = t2drl_init_batch(KEY, CFG_VMAP, B)
    ts_f, st_f = run_training(ts_f, CFG_FUSED, key, jnp.arange(1))
    ts_v, st_v = run_training(ts_v, CFG_VMAP, key, jnp.arange(1))
    _tree_close(st_f, st_v)
    _tree_close(ts_f, ts_v)


def test_training_b1_fused_vs_vmap_bit_identical():
    """B == 1 bypasses batching entirely in BOTH impls (the legacy
    unbatched program), so full training runs stay exact."""
    key = jax.random.PRNGKey(5)
    ts_f = t2drl_init_batch(KEY, CFG_FUSED, 1)
    ts_v = t2drl_init_batch(KEY, CFG_VMAP, 1)
    ts_f, st_f = run_training(ts_f, CFG_FUSED, key, jnp.arange(2))
    ts_v, st_v = run_training(ts_v, CFG_VMAP, key, jnp.arange(2))
    _tree_equal(st_f, st_v)
    _tree_equal(ts_f, ts_v)


# -- population schedules -----------------------------------------------------

def test_validate_pop_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown population keys"):
        _validate_pop({"momentum": jnp.zeros(2)}, CFG_FUSED, 2, 3)


def test_validate_pop_requires_fused_independent():
    with pytest.raises(ValueError, match="independent_impl='fused'"):
        _validate_pop({"eps": jnp.zeros(2)}, CFG_VMAP, 2, 3)
    shared = dataclasses.replace(CFG, policy="shared")
    with pytest.raises(ValueError, match="policy='independent'"):
        _validate_pop({"eps": jnp.zeros(2)}, shared, 2, 3)


def test_validate_pop_rejects_bad_shape():
    with pytest.raises(ValueError, match="must be"):
        _validate_pop({"eps": jnp.zeros((4, 2))}, CFG_FUSED, 2, 3)


def test_validate_pop_broadcasts_and_fills_lr_partner():
    pop = _validate_pop({"lr_actor": jnp.asarray([1e-4, 2e-4])},
                        CFG_FUSED, 2, 3)
    assert pop["lr_actor"].shape == (3, 2)
    np.testing.assert_allclose(np.asarray(pop["lr_critic"]),
                               np.full((3, 2), CFG.lr_critic))


def test_population_zero_lr_freezes_member():
    """lr = 0 for member 0 leaves its D3PG actor/critic at init while
    member 1 trains — the per-member LR lever reaches every update.  (The
    DDQN lever rides the same ``step`` plumbing but its updates gate on
    ``fbuf size > batch``, which a 2-episode run never reaches.)"""
    B = 2
    cfg = dataclasses.replace(CFG_FUSED, warmup=2)
    ts0 = t2drl_init_batch(KEY, cfg, B)
    init_d3pg = jax.tree.map(jnp.copy, ts0["d3pg"])
    pop = {"lr_actor": jnp.asarray([0.0, 1e-4]),
           "lr_critic": jnp.asarray([0.0, 1e-4]),
           "lr_ddqn": jnp.asarray([0.0, 1e-3])}
    ts, _ = run_training(ts0, cfg, jax.random.PRNGKey(5), jnp.arange(2),
                         pop=pop)
    frozen = jax.tree.map(lambda x: x[0], ts["d3pg"])
    init0 = jax.tree.map(lambda x: x[0], init_d3pg)
    for k in ("actor", "critic"):
        _tree_equal(frozen[k], init0[k])
    trained = jax.tree.map(lambda x: x[1], ts["d3pg"])
    init1 = jax.tree.map(lambda x: x[1], init_d3pg)
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(trained["actor"]),
                                jax.tree.leaves(init1["actor"])))
    assert moved, "member 1 actor params never moved"


def test_population_eps_isolated_per_member():
    """Per-member epsilon reaches the DDQN action draw AND stays isolated:
    changing member 1's eps leaves member 0's trajectory bitwise unchanged
    (independent cells) while member 1's trajectory actually changes."""
    B = 2
    cfg = dataclasses.replace(CFG_FUSED, warmup=2)
    key = jax.random.PRNGKey(5)
    ts = t2drl_init_batch(KEY, cfg, B)
    _, st_a = run_training(ts, cfg, key, jnp.arange(2),
                           pop={"eps": jnp.asarray([0.0, 0.0])})
    ts = t2drl_init_batch(KEY, cfg, B)
    _, st_b = run_training(ts, cfg, key, jnp.arange(2),
                           pop={"eps": jnp.asarray([0.0, 1.0])})
    _tree_equal({k: v[:, 0] for k, v in st_a.items()},
                {k: v[:, 0] for k, v in st_b.items()})
    changed = any(
        not np.array_equal(np.asarray(st_a[k][:, 1]),
                           np.asarray(st_b[k][:, 1])) for k in st_a)
    assert changed, "member 1's eps change never reached its trajectory"


# -- shard_map multi-device placement -----------------------------------------

_SHARD_SCRIPT = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core import (EnvCfg, T2DRLCfg, run_training,
                            run_training_sharded, t2drl_init_batch)

    assert jax.device_count() == 2, jax.devices()
    ENV = EnvCfg(U=4, M=4, T=3, K=3)
    cfg = T2DRLCfg(env=ENV, policy="independent", warmup=5, lr_actor=1e-4,
                   lr_critic=1e-4, lr_ddqn=1e-3, L=2,
                   eps_decay_episodes=4, seed=0)
    key, ep = jax.random.PRNGKey(5), jnp.arange(2)
    B = 4

    def leaves(t):
        return [np.asarray(x) for x in jax.tree.leaves(t)]

    # rollout: sharded == single-device to float32 round-off (different
    # whole-programs -> context-dependent XLA CPU codegen, as in the
    # fused-vs-vmap pins); discrete stats must stay exact
    ts = t2drl_init_batch(jax.random.PRNGKey(0), cfg, B)
    ts_s, st_s = run_training_sharded(ts, cfg, key, ep, train=False)
    ts2 = t2drl_init_batch(jax.random.PRNGKey(0), cfg, B)
    ts_r, st_r = run_training(ts2, cfg, key, ep, train=False)
    for a, b in zip(leaves((ts_s, st_s)), leaves((ts_r, st_r))):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(st_s["hit_ratio"]),
                                  np.asarray(st_r["hit_ratio"]))

    # training: same tolerance contract as fused-vs-vmap
    ts = t2drl_init_batch(jax.random.PRNGKey(0), cfg, B)
    ts_s, st_s = run_training_sharded(ts, cfg, key, ep)
    ts2 = t2drl_init_batch(jax.random.PRNGKey(0), cfg, B)
    ts_r, st_r = run_training(ts2, cfg, key, ep)
    for a, b in zip(leaves((ts_s, st_s)), leaves((ts_r, st_r))):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
    print("SHARD-EQUIV-OK")
""")


def test_shard_map_equivalence_forced_devices():
    """run_training_sharded == run_training under a forced 2-device host
    platform.  Runs in a subprocess: the device count must be set before
    the first jax initialization, which this process has already done."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    assert "SHARD-EQUIV-OK" in out.stdout
