"""Pure-Python reference implementations of the classical cache policies.

Operation-for-operation mirrors of ``repro.core.cache_policies`` (seeded
from the dict-based ARCache idiom in SNIPPETS.md #1, re-expressed over the
fixed model universe): plain lists/ints, sequential loops, NO jax — the
independent oracle the differential harness (``tests/test_cachers.py``)
drives in lockstep with the jitted state machines.  All arithmetic is
integer (size units), so agreement is exact, not approximate: every
``access`` must produce the same ``hit``/``admitted``/``evicted`` trace
and the same resident set as the jitted ``cache_access``.

Tie-break contract (DESIGN.md §14): eviction victims minimize
``(score, index)`` — the Python ``min`` over tuples mirrors jax's
argmin-first-occurrence over a masked score array.
"""
from __future__ import annotations

import numpy as np


class _RefBase:
    """Shared state layout: membership sets as bool arrays over the M model
    ids, timestamp/frequency arrays, a logical clock — exactly the
    ``cache_state_init`` leaves."""

    def __init__(self, M, c_units, cap_units):
        self.M = int(M)
        self.cu = [int(c) for c in c_units]
        self.cap = int(cap_units)
        self.in_t1 = np.zeros(M, bool)
        self.in_t2 = np.zeros(M, bool)
        self.in_b1 = np.zeros(M, bool)
        self.in_b2 = np.zeros(M, bool)
        self.last = np.full(M, -1, np.int64)
        self.glast = np.full(M, -1, np.int64)
        self.freq = np.zeros(M, np.int64)
        self.time = 0
        self.p = 0

    def rho(self):
        return (self.in_t1 | self.in_t2).astype(np.float32)

    def _units(self, members):
        return sum(self.cu[i] for i in range(self.M) if members[i])

    def _evict_oldest(self, members, order, budget, evicted=None):
        """Evict lowest-(order, index) members until they fit ``budget``."""
        for _ in range(self.M):
            if self._units(members) <= budget or not members.any():
                break
            v = min((i for i in range(self.M) if members[i]),
                    key=lambda i: (order[i], i))
            members[v] = False
            if evicted is not None:
                evicted[v] = True

    def _noop(self):
        return {"hit": False, "admitted": False,
                "evicted": np.zeros(self.M, bool)}


class RefLRU(_RefBase):
    def access(self, m, valid=True):
        if not valid:
            return self._noop()
        self.time += 1
        hit = bool(self.in_t1[m])
        fits = self.cu[m] <= self.cap
        admit = (not hit) and fits
        ev = np.zeros(self.M, bool)
        if admit:
            self._evict_oldest(self.in_t1, self.last,
                               self.cap - self.cu[m], ev)
            self.in_t1[m] = True
        if hit or admit:
            self.last[m] = self.time
        return {"hit": hit, "admitted": admit, "evicted": ev}


class RefLFU(_RefBase):
    def _evict_lfu(self, budget, ev):
        for _ in range(self.M):
            if self._units(self.in_t1) <= budget or not self.in_t1.any():
                break
            fmin = min(self.freq[i] for i in range(self.M) if self.in_t1[i])
            v = min((i for i in range(self.M)
                     if self.in_t1[i] and self.freq[i] == fmin),
                    key=lambda i: (self.last[i], i))
            self.in_t1[v] = False
            self.freq[v] = 0
            ev[v] = True

    def access(self, m, valid=True):
        if not valid:
            return self._noop()
        self.time += 1
        hit = bool(self.in_t1[m])
        fits = self.cu[m] <= self.cap
        admit = (not hit) and fits
        ev = np.zeros(self.M, bool)
        if hit:
            self.freq[m] += 1
        elif admit:
            self._evict_lfu(self.cap - self.cu[m], ev)
            self.in_t1[m] = True
            self.freq[m] = 1
        if hit or admit:
            self.last[m] = self.time
        return {"hit": hit, "admitted": admit, "evicted": ev}


class RefLRUGhost(_RefBase):
    """Admission-filtered LRU: ghost list as doorkeeper (cache list in
    ``in_t1``, ghost list in ``in_b1``)."""

    def access(self, m, valid=True):
        if not valid:
            return self._noop()
        self.time += 1
        hit = bool(self.in_t1[m])
        fits = self.cu[m] <= self.cap
        ghost_hit = (not hit) and bool(self.in_b1[m])
        admit = ghost_hit and fits
        record = (not hit) and not ghost_hit
        ev = np.zeros(self.M, bool)
        if admit:
            self._evict_oldest(self.in_t1, self.last,
                               self.cap - self.cu[m], ev)
            self.in_t1[m] = True
            self.in_b1[m] = False
        if hit or admit:
            self.last[m] = self.time
        for v in range(self.M):
            if ev[v]:
                self.in_b1[v] = True
                self.glast[v] = self.time
        if record:
            self.in_b1[m] = True
            self.glast[m] = self.time
        self._evict_oldest(self.in_b1, self.glast, self.cap)
        return {"hit": hit, "admitted": admit, "evicted": ev}


class RefARC(_RefBase):
    """Scan-safe, size-aware ARC (DESIGN.md §14): every cache eviction
    ghosts, the directory invariants (T1+B1 <= cap, total <= 2*cap, in
    size units) are restored by post-hoc oldest-ghost trims."""

    def access(self, m, valid=True):
        if not valid:
            return self._noop()
        self.time += 1
        t = self.time
        size_m = self.cu[m]
        fits = size_m <= self.cap
        hit = bool(self.in_t1[m] or self.in_t2[m])
        b1_hit = (not hit) and bool(self.in_b1[m])
        b2_hit = (not hit) and bool(self.in_b2[m])
        admit = (not hit) and fits
        b1u, b2u = self._units(self.in_b1), self._units(self.in_b2)
        if b1_hit:
            d1 = max(size_m, (b2u // max(b1u, 1)) * size_m)
            self.p = min(self.p + d1, self.cap)
        elif b2_hit:
            d2 = max(size_m, (b1u // max(b2u, 1)) * size_m)
            self.p = max(self.p - d2, 0)
        ev = np.zeros(self.M, bool)
        if admit:                                  # REPLACE
            for _ in range(self.M):
                t1u = self._units(self.in_t1)
                t2u = self._units(self.in_t2)
                if t1u + t2u + size_m <= self.cap:
                    break
                any1, any2 = self.in_t1.any(), self.in_t2.any()
                if not (any1 or any2):
                    break
                pick1 = any1 and ((t1u > self.p)
                                  or (b2_hit and t1u == self.p)
                                  or not any2)
                src, dst = ((self.in_t1, self.in_b1) if pick1
                            else (self.in_t2, self.in_b2))
                v = min((i for i in range(self.M) if src[i]),
                        key=lambda i: (self.last[i], i))
                src[v] = False
                dst[v] = True
                self.glast[v] = t
                ev[v] = True
        if hit:                                    # T1 -> T2 promotion
            self.in_t1[m] = False
            self.in_t2[m] = True
        elif admit:
            if b1_hit or b2_hit:                   # ghost hit -> frequent
                self.in_b1[m] = False
                self.in_b2[m] = False
                self.in_t2[m] = True
            else:                                  # cold miss -> recent
                self.in_t1[m] = True
        if hit or admit:
            self.last[m] = t
        t1u = self._units(self.in_t1)
        self._evict_oldest(self.in_b1, self.glast, max(self.cap - t1u, 0))
        tot = (t1u + self._units(self.in_t2) + self._units(self.in_b1))
        self._evict_oldest(self.in_b2, self.glast,
                           max(2 * self.cap - tot, 0))
        return {"hit": hit, "admitted": admit, "evicted": ev}


CACHE_REFS = {"lru": RefLRU, "lfu": RefLFU, "lru-ghost": RefLRUGhost,
              "arc": RefARC}
