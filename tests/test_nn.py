"""Unit tests for the nn substrate: norms, rope, attention (incl. decode
consistency), MLA absorbed-decode equivalence, MoE dispatch, SSD."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn import core
from repro.nn.attention import (AttnCfg, attn_decode, attn_forward,
                                attn_init, init_kv_cache)
from repro.nn.mla import (MLACfg, init_mla_cache, mla_decode, mla_forward,
                          mla_init)
from repro.nn.moe import MoECfg, moe_apply, moe_init
from repro.nn.rotary import apply_rope, rope_cos_sin
from repro.nn.ssm import SSMCfg, init_ssm_state, ssm_decode, ssm_forward, ssm_init

KEY = jax.random.PRNGKey(0)
F32 = dict(compute_dtype=jnp.float32)


def test_rmsnorm_unit_scale():
    p = core.rmsnorm_init(16)
    x = jax.random.normal(KEY, (4, 16)) * 10
    y = core.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_layernorm_nonparametric_is_standardising():
    p = core.layernorm_init(16, elementwise=False)
    assert p == {}
    x = jax.random.normal(KEY, (4, 16)) * 3 + 5
    y = core.layernorm(p, x)
    np.testing.assert_allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(y, -1)), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_phase():
    pos = jnp.arange(8)
    cos, sin = rope_cos_sin(pos, 16)
    x = jax.random.normal(KEY, (1, 8, 2, 16))
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot(q_i, k_j) after rope depends only on i - j
    q = jnp.ones((1, 8, 1, 16))
    k = jnp.ones((1, 8, 1, 16))
    qr, kr = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    d02 = jnp.sum(qr[0, 2, 0] * kr[0, 0, 0])
    d13 = jnp.sum(qr[0, 3, 0] * kr[0, 1, 0])
    np.testing.assert_allclose(float(d02), float(d13), rtol=1e-5)


@pytest.mark.parametrize("n_kv,window,qk_norm,bias", [
    (4, None, False, False), (2, None, False, True), (1, 8, True, False)])
def test_attention_decode_matches_forward(n_kv, window, qk_norm, bias):
    cfg = AttnCfg(64, 4, n_kv, 16, qkv_bias=bias, qk_norm=qk_norm,
                  window=window)
    p = attn_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 64))
    full = attn_forward(p, cfg, x, **F32)
    cache = init_kv_cache(2, 16, cfg, jnp.float32)
    y = None
    for t in range(12):
        y, cache = attn_decode(p, cfg, x[:, t:t + 1], cache, jnp.int32(t),
                               **F32)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_mla_absorbed_decode_matches_expanded_forward():
    cfg = MLACfg(64, 4, q_lora_rank=32, kv_lora_rank=32, qk_nope_dim=16,
                 qk_rope_dim=8, v_head_dim=16)
    p = mla_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 10, 64))
    full = mla_forward(p, cfg, x, **F32)
    cache = init_mla_cache(2, 12, cfg, jnp.float32)
    y = None
    for t in range(10):
        y, cache = mla_decode(p, cfg, x[:, t:t + 1], cache, jnp.int32(t),
                              **F32)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_mla_cache_is_compressed():
    """MLA decode cache bytes/token must be (kv_lora + rope_dim), far below
    2·H·Dh — the edge-memory win described in DESIGN.md."""
    cfg = MLACfg(64, 16, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                 v_head_dim=16)
    cache = init_mla_cache(1, 1, cfg)
    per_tok = sum(x.size for x in jax.tree.leaves(cache))
    assert per_tok == cfg.kv_lora_rank + cfg.qk_rope_dim
    assert per_tok < 2 * cfg.n_heads * cfg.v_head_dim


def test_moe_full_capacity_matches_dense_computation():
    cfg = MoECfg(32, 64, n_experts=4, top_k=2, capacity_factor=64.0)
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 32))
    y, aux = moe_apply(p, cfg, x, compute_dtype=jnp.float32)
    # dense reference: weighted sum over top-k experts, no capacity
    xt = np.asarray(x).reshape(16, 32)
    logits = xt @ np.asarray(p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    w, ids = jax.lax.top_k(probs, 2)
    w = np.asarray(w / w.sum(-1, keepdims=True))
    up, gate, down = (np.asarray(p[k], np.float32)
                      for k in ("up", "gate", "down"))
    yr = np.zeros_like(xt)
    for t in range(16):
        for j in range(2):
            e = int(ids[t, j])
            h = xt[t] @ up[e]
            g = xt[t] @ gate[e]
            yr[t] += w[t, j] * ((g / (1 + np.exp(-g))) * h) @ down[e]
    np.testing.assert_allclose(np.asarray(y).reshape(16, 32), yr,
                               rtol=2e-3, atol=2e-3)
    assert float(aux) > 0.0


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor=1.0 at most cap tokens per expert contribute."""
    cfg = MoECfg(16, 32, n_experts=2, top_k=1, capacity_factor=1.0)
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 16, 16))
    y, _ = moe_apply(p, cfg, x, compute_dtype=jnp.float32)
    assert not bool(jnp.isnan(y).any())


def test_ssm_decode_matches_forward():
    cfg = SSMCfg(32, 64, head_dim=16, n_groups=1, d_state=8, chunk=8)
    p = ssm_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 12, 32))
    full = ssm_forward(p, cfg, x, **F32)
    st = init_ssm_state(2, cfg, jnp.float32)
    y = None
    for t in range(12):
        y, st = ssm_decode(p, cfg, x[:, t:t + 1], st, **F32)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_ssm_prefill_state_continues_decode():
    cfg = SSMCfg(32, 64, head_dim=16, n_groups=1, d_state=8, chunk=4)
    p = ssm_init(KEY, cfg)
    x = jax.random.normal(KEY, (1, 9, 32))
    full = ssm_forward(p, cfg, x, **F32)
    _, st = ssm_forward(p, cfg, x[:, :8], return_state=True, **F32)
    st = {"conv": st["conv"], "ssm": st["ssm"]}
    y, _ = ssm_decode(p, cfg, x[:, 8:9], st, **F32)
    np.testing.assert_allclose(np.asarray(y[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_moe_shardmap_matches_gspmd_on_host_mesh():
    """The shard_map expert-parallel path must agree with the global-scatter
    path (exercised on a 1x1 host mesh; the multi-device equivalence is
    covered by the dry-run and a calibration script)."""
    import dataclasses
    from repro.nn import sharding as shlib
    cfg = MoECfg(32, 64, n_experts=4, top_k=2, n_shared=1,
                 capacity_factor=8.0)
    cfg_sm = dataclasses.replace(cfg, dispatch="shardmap")
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 8, 32))
    y_ref, _ = moe_apply(p, cfg, x, compute_dtype=jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shlib.use_mesh(mesh), mesh:
        y_sm, _ = jax.jit(lambda p, x: moe_apply(p, cfg_sm, x,
                                                 compute_dtype=jnp.float32))(p, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sm),
                               rtol=2e-4, atol=2e-4)
