"""Environment tests (Eqs. 1-10, 23) + hypothesis property tests on the
system's invariants (amender simplexes, quality monotonicity, reward
bounds)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (EnvCfg, amend_actions, env_reset, env_step_slot,
                        make_models, observe, slot_metrics, tv_quality,
                        gen_delay)
from repro.core.env import MB_BITS, env_new_frame
from repro.core.quality import A1, A2, A3, A4, B1, B2

CFG = EnvCfg(U=6, M=5)
KEY = jax.random.PRNGKey(0)
MODELS = make_models(KEY, CFG)


# -- fitted curves ------------------------------------------------------------

def test_tv_quality_piecewise_endpoints():
    assert float(tv_quality(0.0)) == A2
    assert float(tv_quality(A1)) == A2
    assert float(tv_quality(A3)) == A4
    assert float(tv_quality(1000.0)) == A4
    mid = float(tv_quality((A1 + A3) / 2))
    assert A4 < mid < A2


@given(st.floats(0, 1000), st.floats(0, 1000))
@settings(max_examples=50, deadline=None)
def test_tv_quality_monotone_nonincreasing(s1, s2):
    lo, hi = min(s1, s2), max(s1, s2)
    assert float(tv_quality(hi)) <= float(tv_quality(lo)) + 1e-6


@given(st.floats(0, 1000))
@settings(max_examples=30, deadline=None)
def test_gen_delay_affine(steps):
    np.testing.assert_allclose(float(gen_delay(steps)), B1 * steps + B2,
                               rtol=1e-6)


# -- amender invariants ---------------------------------------------------------

@given(st.integers(0, 2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_amender_simplex_invariants(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    raw = jax.random.uniform(k1, (2 * CFG.U,))
    req = jax.random.randint(k2, (CFG.U,), 0, CFG.M)
    rho = (jax.random.uniform(k3, (CFG.M,)) > 0.5).astype(jnp.float32)
    b, xi = amend_actions(raw, req, rho, CFG.U)
    # (11e): bandwidth simplex
    assert abs(float(jnp.sum(b)) - 1.0) < 1e-4
    assert float(jnp.min(b)) >= 0.0
    # (11f): compute simplex (sums to 1 iff any request cached, else 0)
    gate = np.asarray(rho)[np.asarray(req)]
    s = float(jnp.sum(xi))
    if gate.sum() > 0:
        assert abs(s - 1.0) < 1e-4
    else:
        assert s < 1e-4
    # (11g): no compute to un-cached requests
    assert float(jnp.max(jnp.asarray(xi) * (1 - gate))) < 1e-6


# -- env dynamics ----------------------------------------------------------------

def test_env_reset_and_step_shapes():
    st_ = env_reset(KEY, CFG)
    assert st_.pos.shape == (CFG.U, 2)
    assert st_.h.shape == (CFG.U,)
    assert int(jnp.max(st_.req)) < CFG.M
    b = jnp.full((CFG.U,), 1.0 / CFG.U)
    xi = jnp.full((CFG.U,), 1.0 / CFG.U)
    nxt, r, m = env_step_slot(st_, CFG, MODELS, b, xi)
    assert np.isfinite(float(r)) and float(r) < 0.0  # reward = -utility
    assert m["G"].shape == (CFG.U,)
    # positions stay in the square
    assert float(jnp.min(nxt.pos)) >= 0.0
    assert float(jnp.max(nxt.pos)) <= CFG.area


def test_uncached_requests_get_cloud_quality_and_delay():
    st_ = env_reset(KEY, CFG)
    st_ = st_._replace(rho=jnp.zeros(CFG.M))  # nothing cached
    b = jnp.full((CFG.U,), 1.0 / CFG.U)
    xi = jnp.zeros((CFG.U,))
    m = slot_metrics(st_, CFG, MODELS, b, xi)
    req = np.asarray(st_.req)
    np.testing.assert_allclose(np.asarray(m["quality"]),
                               np.asarray(MODELS.a4)[req], rtol=1e-6)
    expect_gt = np.asarray(MODELS.b1)[req] * np.asarray(MODELS.a3)[req] \
        + np.asarray(MODELS.b2)[req]
    np.testing.assert_allclose(np.asarray(m["delay_gt"]), expect_gt,
                               rtol=1e-6)
    # backhaul adds delay vs the cached path
    st_c = st_._replace(rho=jnp.ones(CFG.M))
    m_c = slot_metrics(st_c, CFG, MODELS, b, xi)
    assert float(jnp.min(m["delay_up"] - m_c["delay_up"])) > 0.0


def test_more_bandwidth_lowers_upload_delay():
    st_ = env_reset(KEY, CFG)
    b_small = jnp.full((CFG.U,), 0.01)
    b_big = jnp.full((CFG.U,), 1.0 / CFG.U)
    xi = jnp.full((CFG.U,), 1.0 / CFG.U)
    d_small = slot_metrics(st_, CFG, MODELS, b_small, xi)["delay_up"]
    d_big = slot_metrics(st_, CFG, MODELS, b_big, xi)["delay_up"]
    assert float(jnp.max(d_big - d_small)) < 0.0


def test_zipf_popularity_skews_requests():
    cfg = EnvCfg(U=4000, M=10, gammas=(1.5, 1.5, 1.5))
    models = make_models(KEY, cfg)
    st_ = env_reset(KEY, cfg)
    counts = np.bincount(np.asarray(st_.req), minlength=10)
    assert counts[0] > counts[-1] * 2  # strong skew at gamma=1.5


def test_frame_transition_changes_gamma_markov():
    st_ = env_reset(KEY, CFG)
    seen = set()
    s = st_
    for _ in range(20):
        s = env_new_frame(s, CFG, jnp.ones(CFG.M))
        seen.add(int(s.gamma_idx))
    assert seen <= {0, 1, 2} and len(seen) >= 2


def test_observation_dimensions_match_paper():
    st_ = env_reset(KEY, CFG)
    obs = observe(st_, CFG, MODELS)
    assert obs.shape == (4 * CFG.U + CFG.M,)  # Eq. (21)
    assert np.all(np.isfinite(np.asarray(obs)))


def test_deadline_violation_penalised_in_reward():
    from repro.core import slot_reward
    st_ = env_reset(KEY, CFG)
    b = jnp.full((CFG.U,), 1.0 / CFG.U)
    xi = jnp.full((CFG.U,), 1.0 / CFG.U)
    m = slot_metrics(st_, CFG, MODELS, b, xi)
    r = float(slot_reward(m, CFG))
    g_only = -float(jnp.mean(m["G"]))
    viol = float(jnp.mean((m["d_tl"] > CFG.tau).astype(jnp.float32)))
    np.testing.assert_allclose(r, g_only - viol * CFG.chi, rtol=1e-5)
