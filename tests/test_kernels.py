"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, Hkv, L, S, D, window, dtype)
    (2, 4, 2, 128, 128, 64, None, jnp.float32),
    (1, 8, 8, 256, 256, 128, None, jnp.float32),
    (1, 4, 1, 256, 256, 64, 64, jnp.float32),
    (2, 2, 2, 96, 96, 32, None, jnp.float32),      # unaligned -> padding
    (1, 4, 2, 128, 128, 64, None, jnp.bfloat16),
    (1, 2, 1, 64, 64, 128, 32, jnp.bfloat16),
]


@pytest.mark.parametrize("B,H,Hkv,L,S,D,window,dtype", FLASH_CASES)
def test_flash_attention_matches_ref(B, H, Hkv, L, S, D, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, L, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              bq=64, bk=64)
    expect = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_attention_rows_sum_to_one_property():
    """Online softmax must renormalise exactly: attention of constant V
    returns that constant."""
    B, H, L, D = 1, 2, 128, 64
    q = jax.random.normal(KEY, (B, L, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, L, H, D))
    v = jnp.ones((B, L, H, D))
    out = ops.flash_attention(q, k, v, causal=True, bq=32, bk=32)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    # (B, L, H, P, G, N, chunk)
    (2, 64, 4, 16, 1, 16, 16),
    (1, 128, 8, 32, 2, 64, 32),
    (2, 40, 4, 8, 2, 16, 16),      # L not divisible by chunk -> padding
    (1, 256, 2, 64, 1, 128, 128),
]


@pytest.mark.parametrize("B,L,H,P,G,N,chunk", SSD_CASES)
def test_ssd_scan_matches_ref(B, L, H, P, G, N, chunk):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    D = jnp.ones((H,))
    y, s = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk)
    yr, sr = ref.ssd_scan_ref(x, dt, A, Bm, Cm, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


def test_ssd_matches_stepwise_recurrence():
    """The chunked SSD (any chunking) must equal the sequential SSM."""
    B, L, H, P, G, N = 1, 24, 2, 4, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, L, G, N))
    Cm = jax.random.normal(ks[4], (B, L, G, N))
    D = jnp.zeros((H,))
    y, _ = ops.ssd_scan(x, dt, A, Bm, Cm, D, chunk=8)
    S = np.zeros((B, H, P, N))
    Bf = np.repeat(np.asarray(Bm), H // G, 2)
    Cf = np.repeat(np.asarray(Cm), H // G, 2)
    for t in range(L):
        dA = np.exp(np.asarray(dt)[:, t] * np.asarray(A)[None])
        S = S * dA[:, :, None, None] + np.einsum(
            "bh,bhn,bhp->bhpn", np.asarray(dt)[:, t], Bf[:, t],
            np.asarray(x)[:, t])
        yt = np.einsum("bhn,bhpn->bhp", Cf[:, t], S)
        np.testing.assert_allclose(np.asarray(y)[:, t], yt,
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ddpm step
# ---------------------------------------------------------------------------

DDPM_CASES = [
    ((4, 20), jnp.float32, 0), ((4, 20), jnp.float32, 3),
    ((2, 3, 40), jnp.float32, 1), ((8, 256), jnp.bfloat16, 2),
    ((1, 7), jnp.float32, 0),
]


@pytest.mark.parametrize("shape,dtype,l_rev", DDPM_CASES)
def test_ddpm_step_matches_ref(shape, dtype, l_rev):
    ks = jax.random.split(KEY, 3)
    x = jax.random.normal(ks[0], shape, dtype)
    e = jax.random.normal(ks[1], shape, dtype)
    n = jax.random.normal(ks[2], shape, dtype)
    alpha, abar, btilde = 0.9, 0.5, 0.04
    out = ops.ddpm_step(x, e, n, jnp.float32(alpha), jnp.float32(abar),
                        jnp.float32(btilde), jnp.int32(l_rev))
    expect = ref.ddpm_step_ref(x, e, n, alpha, abar, btilde, l_rev)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_ddpm_step_last_step_is_deterministic():
    x = jax.random.normal(KEY, (4, 16))
    e = jax.random.normal(jax.random.fold_in(KEY, 1), (4, 16))
    n1 = jax.random.normal(jax.random.fold_in(KEY, 2), (4, 16))
    n2 = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 16))
    a = [jnp.float32(0.9), jnp.float32(0.5), jnp.float32(0.04)]
    o1 = ops.ddpm_step(x, e, n1, *a, jnp.int32(0))
    o2 = ops.ddpm_step(x, e, n2, *a, jnp.int32(0))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2))
