"""Fleet serving twin (DESIGN.md §11): same-seed determinism pin, request
conservation, histogram quantiles, cloud-fallback semantics, scenario
traffic scaling, and checkpointed policy deployment bit-identity."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint import load_train_state, save_train_state
from repro.core import (EnvCfg, T2DRLCfg, eval_t2drl, export_policy,
                        greedy_frame_cache, t2drl_init, t2drl_init_batch,
                        train_t2drl)
from repro.fleet import FleetCfg, latency_quantiles, simulate_fleet
from repro.scenarios import build_scenario

ENV = EnvCfg(U=4, M=4, T=3, K=3)
CFG = T2DRLCfg(env=ENV, warmup=5, lr_actor=1e-4, lr_critic=1e-4,
               lr_ddqn=1e-3, L=2, eps_decay_episodes=4, seed=0)
RCARS = T2DRLCfg(env=ENV, allocator="rcars", cacher="random", L=2, seed=0)
FCFG = FleetCfg(ticks_per_slot=5, arrivals_per_user_s=0.5)

SCALARS = ("requests", "admitted", "dropped", "truncated", "slo_viol_rate",
           "deadline_miss_rate", "mean_latency_s", "mean_wait_s", "p50_s",
           "p95_s", "p99_s", "end_backlog_s", "mean_backlog_s")


@pytest.fixture(scope="module")
def ts_t2drl():
    ts, _ = train_t2drl(CFG, episodes=2)
    return ts


@pytest.fixture(scope="module")
def ts_rcars():
    k_init, _ = jax.random.split(jax.random.PRNGKey(RCARS.seed))
    return t2drl_init(k_init, RCARS)


@pytest.fixture(scope="module")
def fleet_res(ts_t2drl):
    return simulate_fleet(ts_t2drl, CFG, FCFG, num_cells=2, seed=3)


# -- determinism + conservation -----------------------------------------------

def test_same_seed_determinism_pin(ts_t2drl, fleet_res):
    again = simulate_fleet(ts_t2drl, CFG, FCFG, num_cells=2, seed=3)
    for k in SCALARS:
        assert fleet_res[k] == again[k], k
    np.testing.assert_array_equal(fleet_res["hist"], again["hist"])
    np.testing.assert_array_equal(fleet_res["backlog_curve"],
                                  again["backlog_curve"])


def test_different_seed_changes_traffic(ts_t2drl, fleet_res):
    other = simulate_fleet(ts_t2drl, CFG, FCFG, num_cells=2, seed=4)
    assert other["requests"] != fleet_res["requests"]


def test_request_conservation(fleet_res):
    # every truncation-surviving arrival is either admitted or dropped,
    # and every admitted request contributed one histogram entry
    assert fleet_res["requests"] == pytest.approx(
        fleet_res["admitted"] + fleet_res["dropped"])
    assert fleet_res["hist"].sum() == pytest.approx(fleet_res["admitted"])
    assert fleet_res["requests"] > 0


def test_backlog_curve_shape_and_positivity(fleet_res):
    assert fleet_res["backlog_curve"].shape == (2, ENV.T * ENV.K)
    assert fleet_res["peak_backlog_s"] >= fleet_res["mean_backlog_s"] >= 0.0


# -- histogram quantiles ------------------------------------------------------

def test_latency_quantiles_interpolation():
    hist = np.zeros(10)
    hist[2] = 100.0                      # all mass in [2, 3) of [0, 10)
    q = latency_quantiles(hist, 10.0, qs=(0.5,))
    assert q[0.5] == pytest.approx(2.5)


def test_latency_quantiles_overflow_and_empty():
    hist = np.zeros(10)
    hist[-1] = 5.0                       # all mass in the overflow bin
    assert latency_quantiles(hist, 10.0, qs=(0.99,))[0.99] == 10.0
    assert np.isnan(latency_quantiles(np.zeros(4), 1.0, qs=(0.5,))[0.5])


def test_latency_quantiles_single_interior_bucket():
    # all mass in one interior bin: quantiles interpolate linearly
    # within that bin's edges
    hist = np.zeros(4)
    hist[1] = 8.0                        # [1, 2) of [0, 4)
    q = latency_quantiles(hist, 4.0, qs=(0.25, 0.5, 0.75))
    assert q[0.25] == pytest.approx(1.25)
    assert q[0.5] == pytest.approx(1.5)
    assert q[0.75] == pytest.approx(1.75)


def test_latency_quantiles_one_bin_histogram():
    # a 1-bin histogram is all overflow: any mass reports hist_max;
    # no mass still reports NaN, not hist_max
    assert latency_quantiles(np.array([3.0]), 7.0, qs=(0.5,))[0.5] == 7.0
    assert np.isnan(latency_quantiles(np.array([0.0]), 7.0, qs=(0.5,))[0.5])


def test_frame_series_shapes_and_bounds(fleet_res):
    """The per-frame telemetry series (DESIGN.md §15): one entry per
    frame, rates in [0, 1], ordered quantiles where defined."""
    fr = fleet_res["frames"]
    assert fr["frame"] == list(range(ENV.T))
    for k in ("p50_s", "p95_s", "p99_s", "drop_rate", "slo_viol_rate",
              "mean_backlog_s"):
        assert len(fr[k]) == ENV.T, k
    for t in range(ENV.T):
        assert 0.0 <= fr["drop_rate"][t] <= 1.0
        assert 0.0 <= fr["slo_viol_rate"][t] <= 1.0
        assert fr["mean_backlog_s"][t] >= 0.0
        p50, p95, p99 = fr["p50_s"][t], fr["p95_s"][t], fr["p99_s"][t]
        if not np.isnan(p50):            # NaN = no admissions this frame
            assert p50 <= p95 <= p99


# -- policy export ------------------------------------------------------------

def test_export_policy_contents(ts_t2drl, ts_rcars):
    pol = export_policy(ts_t2drl, CFG)
    assert set(pol) == {"actor", "ddqn"}
    assert set(pol["ddqn"]) == {"q"}     # online net only, no target/opt
    assert export_policy(ts_rcars, RCARS) == {}


def test_export_policy_cell_selects_independent_learner():
    k_init, _ = jax.random.split(jax.random.PRNGKey(CFG.seed))
    ts = t2drl_init_batch(k_init, CFG, 2)       # policy="independent"
    for cell in (0, 1):
        pol = export_policy(ts, CFG, cell=cell)
        for a, b in zip(jax.tree.leaves(pol["actor"]),
                        jax.tree.leaves(ts["d3pg"]["actor"])):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(b[cell]))
    p0 = jax.tree.leaves(export_policy(ts, CFG, cell=0)["actor"])
    p1 = jax.tree.leaves(export_policy(ts, CFG, cell=1)["actor"])
    assert any(not np.array_equal(a, b) for a, b in zip(p0, p1))


def test_greedy_entry_points_match_training_primitives(ts_t2drl):
    """Serving-side dispatch pin (DESIGN.md §11 'same amenders' contract):
    greedy_slot_action / greedy_frame_cache must compose exactly the
    primitives the training episode uses at eps = sigma = 0, for every
    allocator/cacher branch."""
    from repro.core import (actor_act, amend_actions, amend_caching,
                            ddqn_act, greedy_frame_cache,
                            greedy_slot_action, make_actor_schedule,
                            observe)
    from repro.core.baselines import (ga_allocate, random_cache,
                                      rcars_allocate, static_popular_cache)
    from repro.core.env import env_reset, env_set_cache
    models = ts_t2drl["models"]
    env = env_set_cache(env_reset(jax.random.PRNGKey(7), ENV),
                        static_popular_cache(models, ENV))
    ka = jax.random.PRNGKey(8)
    pol = export_policy(ts_t2drl, CFG)
    # d3pg allocator: actor -> amender, no exploration noise
    d3 = CFG.d3pg_cfg()
    raw = actor_act(pol["actor"], d3, make_actor_schedule(d3),
                    observe(env, ENV, models, None), ka)
    b_ref, xi_ref = amend_actions(raw, env.req, env.rho, ENV.U)
    b, xi = greedy_slot_action(pol, CFG, env, models, ka)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))
    # rcars / schrs allocators
    b, xi = greedy_slot_action({}, RCARS, env, models, ka)
    b_ref, xi_ref = rcars_allocate(env, ENV)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))
    schrs = dataclasses.replace(RCARS, allocator="schrs", cacher="static")
    b, xi = greedy_slot_action({}, schrs, env, models, ka)
    b_ref, xi_ref = ga_allocate(ka, env, ENV, models, schrs.ga)
    np.testing.assert_array_equal(np.asarray(b), np.asarray(b_ref))
    np.testing.assert_array_equal(np.asarray(xi), np.asarray(xi_ref))
    # ddqn cacher at eps = 0, static, random
    dq = CFG.ddqn_cfg()
    a_int = ddqn_act(pol["ddqn"], dq, env.gamma_idx, ka, 0.0)
    rho_ref = amend_caching(a_int, dq, models.c, ENV.C)
    np.testing.assert_array_equal(
        np.asarray(greedy_frame_cache(pol, CFG, models, env.gamma_idx, ka)),
        np.asarray(rho_ref))
    np.testing.assert_array_equal(
        np.asarray(greedy_frame_cache({}, schrs, models, env.gamma_idx, ka)),
        np.asarray(static_popular_cache(models, ENV)))
    np.testing.assert_array_equal(
        np.asarray(greedy_frame_cache({}, RCARS, models, env.gamma_idx, ka)),
        np.asarray(random_cache(ka, models, ENV)))


def test_unregistered_namedtuple_raises_clear_error(tmp_path):
    from repro.core import SlotMod
    bad = {"mod": SlotMod(h_scale=np.float32(1.0), din_scale=np.float32(1.0),
                          burst_prob=np.float32(0.0),
                          burst_model=np.int32(0))}
    with pytest.raises(TypeError, match="unregistered NamedTuple"):
        save_train_state(str(tmp_path / "bad.msgpack"), bad)


# -- checkpointed deployment --------------------------------------------------

def test_checkpoint_roundtrip_bit_identity(tmp_path, ts_t2drl, fleet_res):
    """train -> save -> load -> eval/serve is bit-identical to the live
    state (the ISSUE 3 save->load->eval pin)."""
    path = save_train_state(str(tmp_path / "t2drl.msgpack"), ts_t2drl,
                            meta={"method": "t2drl", "seed": CFG.seed})
    back, meta = load_train_state(path)
    assert meta["method"] == "t2drl" and meta["seed"] == CFG.seed
    assert type(back["models"]).__name__ == "ModelParams"
    for a, b in zip(jax.tree.leaves(ts_t2drl), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ev_live = eval_t2drl(ts_t2drl, CFG, episodes=2)
    ev_back = eval_t2drl(back, CFG, episodes=2)
    for k in ev_live:
        assert float(ev_live[k]) == float(ev_back[k]), k
    served = simulate_fleet(back, CFG, FCFG, num_cells=2, seed=3)
    for k in SCALARS:
        assert served[k] == fleet_res[k], k
    np.testing.assert_array_equal(served["hist"], fleet_res["hist"])


def test_batched_shared_train_state_roundtrip_bit_identity(tmp_path):
    """The unified TrainState layout (DESIGN.md §12) checkpoints uniformly
    across vector-env modes: a batched shared-learner state (per-cell
    models/buffers, single learner) restores bit-identically and evaluates
    identically — no agent-kind or layout special-casing in the codec."""
    cfg = dataclasses.replace(CFG, policy="shared")
    ts, _ = train_t2drl(cfg, episodes=2, num_envs=2)
    path = save_train_state(str(tmp_path / "shared.msgpack"), ts,
                            meta={"policy": "shared", "num_envs": 2})
    back, meta = load_train_state(path)
    assert meta["num_envs"] == 2
    assert set(back) == {"models", "d3pg", "ddqn", "ebuf", "fbuf", "cache"}
    for a, b in zip(jax.tree.leaves(ts), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    ev_live = eval_t2drl(ts, cfg, episodes=2)
    ev_back = eval_t2drl(back, cfg, episodes=2)
    for k in ev_live:
        assert float(ev_live[k]) == float(ev_back[k]), k
    # the exported policy slice is identical too (shared learner: no cell
    # slicing), and serves through the twin deterministically
    pol_live = export_policy(ts, cfg)
    pol_back = export_policy(back, cfg)
    for a, b in zip(jax.tree.leaves(pol_live), jax.tree.leaves(pol_back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    r1 = simulate_fleet(ts, cfg, FCFG, seed=2)
    r2 = simulate_fleet(back, cfg, FCFG, seed=2)
    for k in SCALARS:
        assert r1[k] == r2[k], k


def test_arc_policy_checkpoint_roundtrip(tmp_path):
    """Classical-cacher deployment pin (DESIGN.md §14): train an ARC
    baseline, checkpoint it, restore it, and serve through the twin —
    the frozen resident set survives the round trip bit-identically and
    the restored state serves the exact same traffic outcome."""
    cfg = dataclasses.replace(RCARS, cacher="arc")
    ts, _ = train_t2drl(cfg, episodes=2)
    path = save_train_state(str(tmp_path / "arc.msgpack"), ts,
                            meta={"method": "cacher-arc"})
    back, meta = load_train_state(path)
    assert meta["method"] == "cacher-arc"
    for a, b in zip(jax.tree.leaves(ts), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # exported policy is the frozen resident set, identical across the trip
    pol_live = export_policy(ts, cfg)
    pol_back = export_policy(back, cfg)
    assert set(pol_live) == {"cache"}
    rho_live = np.asarray(pol_live["cache"]["rho"])
    np.testing.assert_array_equal(rho_live,
                                  np.asarray(pol_back["cache"]["rho"]))
    assert rho_live.shape == (ENV.M,)
    assert set(np.unique(rho_live)) <= {0.0, 1.0}
    # the greedy serving entry point reads that set verbatim
    kf = jax.random.PRNGKey(11)
    gi = jax.numpy.zeros((ENV.M,), jax.numpy.int32)
    np.testing.assert_array_equal(
        np.asarray(greedy_frame_cache(pol_back, cfg, ts["models"], gi, kf)),
        rho_live)
    r1 = simulate_fleet(ts, cfg, FCFG, num_cells=1, seed=6)
    r2 = simulate_fleet(back, cfg, FCFG, num_cells=1, seed=6)
    for k in SCALARS:
        assert r1[k] == r2[k], k
    np.testing.assert_array_equal(r1["hist"], r2["hist"])


def test_load_rejects_unknown_format(tmp_path):
    import msgpack
    p = tmp_path / "bad.msgpack"
    p.write_bytes(msgpack.packb({"format": 99, "state": {}}))
    with pytest.raises(ValueError, match="format"):
        load_train_state(str(p))


# -- queueing semantics -------------------------------------------------------

def test_uncached_requests_take_cloud_path_without_queueing(ts_rcars):
    """With zero cache capacity every request goes to the cloud: no edge
    backlog, no queueing wait, no drops — latency is transmission +
    cloud compute only."""
    env0 = dataclasses.replace(ENV, C=0.0)
    cfg0 = dataclasses.replace(RCARS, env=env0)
    k_init, _ = jax.random.split(jax.random.PRNGKey(0))
    ts = t2drl_init(k_init, cfg0)
    res = simulate_fleet(ts, cfg0, FCFG, num_cells=1, seed=0)
    assert res["requests"] > 0
    assert res["dropped"] == 0.0
    assert res["mean_wait_s"] == 0.0
    assert res["end_backlog_s"] == 0.0
    assert res["peak_backlog_s"] == 0.0
    assert res["mean_latency_s"] > 0.0


def test_population_scales_offered_load(ts_rcars):
    """user_counts modulates each cell's arrival rate (fleet 'populations
    are traffic' contract): 4 active users >> 1 active user."""
    lo = simulate_fleet(ts_rcars, RCARS, FCFG, num_cells=2, seed=5,
                        user_counts=(1, 1))
    hi = simulate_fleet(ts_rcars, RCARS, FCFG, num_cells=2, seed=5,
                        user_counts=(4, 4))
    assert hi["requests"] > 2.0 * lo["requests"]


def test_scenario_schedule_is_a_traffic_trace(ts_rcars):
    """A registered scenario drives the twin: flash-crowd's burst schedule
    concentrates arrivals on the hot model and raises offered load
    (din_scale doubles as the load multiplier, DESIGN.md §11)."""
    b = build_scenario("flash-crowd", ENV, num_envs=2)
    res = simulate_fleet(ts_rcars, RCARS, FCFG, num_cells=2, seed=5,
                         mods=b.mods)
    base = simulate_fleet(ts_rcars, RCARS, FCFG, num_cells=2, seed=5)
    assert res["requests"] != base["requests"]
    assert res["requests"] > 0 and base["requests"] > 0


def test_truncation_is_counted_not_silent(ts_rcars):
    stress = FleetCfg(ticks_per_slot=5, arrivals_per_user_s=50.0,
                      max_arrivals=4)
    res = simulate_fleet(ts_rcars, RCARS, stress, num_cells=1, seed=0)
    assert res["truncated"] > 0.0
    assert res["requests"] == pytest.approx(res["admitted"]
                                            + res["dropped"])


# -- batched train states -----------------------------------------------------

def test_batched_ts_fixes_fleet_size(tmp_path):
    cfg = dataclasses.replace(CFG, policy="shared")
    k_init, _ = jax.random.split(jax.random.PRNGKey(cfg.seed))
    ts = t2drl_init_batch(k_init, cfg, 2)
    res = simulate_fleet(ts, cfg, FCFG, seed=0)     # C defaults to B=2
    assert res["num_cells"] == 2
    with pytest.raises(ValueError, match="batched over 2 cells"):
        simulate_fleet(ts, cfg, FCFG, num_cells=3, seed=0)
