import jax
import pytest

# Smoke tests and benches must see the real (single) device — the 512-device
# override lives ONLY in repro.launch.dryrun.


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
