import sys
import types

import jax
import numpy as np
import pytest

# Smoke tests and benches must see the real (single) device — the 512-device
# override lives ONLY in repro.launch.dryrun.


# -- hypothesis shim ----------------------------------------------------------
#
# The property tests use a small slice of hypothesis (given / settings /
# st.integers / st.floats).  When the real package is missing (it is not in
# the base container image), install a deterministic stand-in BEFORE the test
# modules import it: each @given test runs against the range endpoints plus
# seeded uniform draws.  With hypothesis installed (see requirements.txt),
# the real shrinking engine is used instead.

def _install_hypothesis_stub():
    class _Strategy:
        def __init__(self, lo, hi, draw):
            self.lo, self.hi, self.draw = lo, hi, draw

        def examples(self, rng, n):
            out = [self.lo, self.hi]
            out += [self.draw(rng) for _ in range(max(n - 2, 0))]
            return out[:max(n, 1)]

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = lambda lo, hi: _Strategy(
        lo, hi, lambda rng: int(rng.randint(lo, hi)) if hi > lo else lo)
    st_mod.floats = lambda lo, hi: _Strategy(
        float(lo), float(hi), lambda rng: float(rng.uniform(lo, hi)))

    def given(*strats):
        def deco(fn):
            # NB: no functools.wraps — pytest would follow __wrapped__ to
            # the original signature and demand fixtures for the params.
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_stub_max_examples", 20)
                rng = np.random.RandomState(0)
                cases = zip(*(s.examples(rng, n) for s in strats))
                for case in cases:
                    fn(*args, *case, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._stub_inner = fn
            return wrapper
        return deco

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                (getattr(fn, "_stub_inner", fn)
                 )._stub_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given, hyp.settings, hyp.strategies = given, settings, st_mod
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
