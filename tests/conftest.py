import inspect
import sys
import types

import jax
import numpy as np
import pytest

# Smoke tests and benches must see the real (single) device — the 512-device
# override lives ONLY in repro.launch.dryrun.


# -- hypothesis shim ----------------------------------------------------------
#
# The property tests use a small slice of hypothesis (given / settings /
# st.integers / st.floats / st.lists / st.sampled_from / st.composite).
# When the real package is missing (it is not in the base container image),
# install a deterministic stand-in BEFORE the test modules import it: each
# @given test runs against the strategies' boundary values plus seeded
# uniform draws — same cases in every run, so stub-vs-real collection only
# changes the engine, never which tests exist.  With hypothesis installed
# (see requirements.txt), the real shrinking engine is used instead.

def _install_hypothesis_stub():
    class _Strategy:
        def __init__(self, boundaries, draw):
            self.boundaries, self.draw = list(boundaries), draw

        def examples(self, rng, n):
            out = list(self.boundaries)
            out += [self.draw(rng) for _ in range(max(n - len(out), 0))]
            return out[:max(n, 1)]

    def _integers(lo, hi):
        return _Strategy(
            [lo, hi],
            lambda rng: int(rng.randint(lo, hi)) if hi > lo else lo)

    def _floats(lo, hi):
        return _Strategy([float(lo), float(hi)],
                         lambda rng: float(rng.uniform(lo, hi)))

    def _lists(elem, min_size=0, max_size=None):
        if max_size is None:
            raise ValueError("stub st.lists requires an explicit max_size")

        def draw(rng):
            n = int(rng.randint(min_size, max_size)) \
                if max_size > min_size else min_size
            return [elem.draw(rng) for _ in range(n)]

        lo = [elem.boundaries[0]] * min_size
        hi = [elem.boundaries[-1]] * max_size
        return _Strategy([lo, hi], draw)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy([seq[0], seq[-1]],
                         lambda rng: seq[int(rng.randint(0, len(seq)))])

    def _composite(fn):
        # real-hypothesis contract: fn's first arg is a draw callable;
        # @st.composite returns a factory whose calls return a strategy
        def factory(*args, **kwargs):
            return _Strategy(
                [fn(lambda s: s.boundaries[0], *args, **kwargs),
                 fn(lambda s: s.boundaries[-1], *args, **kwargs)],
                lambda rng: fn(lambda s: s.draw(rng), *args, **kwargs))
        factory.__name__ = fn.__name__
        return factory

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.lists = _lists
    st_mod.sampled_from = _sampled_from
    st_mod.composite = _composite

    def given(*strats):
        def deco(fn):
            # like real hypothesis, positional strategies fill the test
            # function's RIGHTMOST parameters; any leading ones (pytest
            # parametrize/fixtures) stay visible through __signature__
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            strat_names = names[len(names) - len(strats):]

            # NB: no functools.wraps — pytest would follow __wrapped__ to
            # the original signature and demand fixtures for the params.
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_stub_max_examples", 20)
                rng = np.random.RandomState(0)
                cases = zip(*(s.examples(rng, n) for s in strats))
                for case in cases:
                    fn(*args, **kwargs, **dict(zip(strat_names, case)))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[n] for n in names
                            if n not in strat_names])
            wrapper._stub_inner = fn
            return wrapper
        return deco

    def settings(max_examples=None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                (getattr(fn, "_stub_inner", fn)
                 )._stub_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given, hyp.settings, hyp.strategies = given, settings, st_mod
    hyp.__stub__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
